//! TDC substrate: DeConv-to-Conv conversion (paper refs [14-16], Fig. 1c/2b)
//! plus the reference DeConv implementations all other layers are validated
//! against.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly (same conventions, same
//! phase/offset derivation); the python tests pin the JAX kernels to the
//! numpy oracle, and the rust property tests pin this module to itself
//! (TDC == standard) and the functional accelerator simulator to this module.
//!
//! Conventions: input `[C_in, H, W]`, deconv filters `[C_in, C_out, K, K]`
//! (conv-transpose layout), output `[C_out, S*H, S*W]` with
//!
//! ```text
//! y[co, oy, ox] = sum x[ci, iy, ix] * w[ci, co, ky, kx]
//!                 where S*iy = oy + P - ky, S*ix = ox + P - kx.
//! ```

use crate::util::elem::Elem;
use crate::util::tensor::{Filter4, Tensor3};

/// K_C = ceil(K_D / S): the TDC-converted Conv kernel width (Table I).
pub fn kc(k: usize, s: usize) -> usize {
    k.div_ceil(s)
}

/// The paper's layer paddings: P=2 for (K=5,S=2); P=1 for (K=4,S=2), (K=3,S=1).
pub fn default_padding(k: usize, s: usize) -> usize {
    (k - s + 1) / 2
}

/// 1D sub-filter plan for one output phase: which taps of the *flipped*
/// kernel it uses and its input offset `d0`:
///
/// `y[S*i + phase] = sum_u g[u] * x[i + u + d0]`, `g[u] = w_flipped[taps[u]]`
/// (taps\[u\] == None for implicit zero-pad taps).
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseTaps {
    pub taps: Vec<Option<usize>>,
    pub d0: isize,
}

impl PhaseTaps {
    /// Number of real (non-padded) taps; this is the structural support
    /// that determines the Winograd sparsity case.
    pub fn real_taps(&self) -> usize {
        self.taps.iter().filter(|t| t.is_some()).count()
    }
}

/// Derive the 1D tap plan for `phase` of a (K, S, P) deconv.
///
/// A phase whose first candidate tap already falls past the kernel
/// (`t0 >= K`, possible for exotic (K, S) combos like K=1, S=2) receives
/// **zero real taps**: an all-padded plan with `d0 = 0`. Downstream,
/// `reorder_filter` turns such degenerate phases into explicitly empty
/// slabs the engine skips.
///
/// Panics if a non-degenerate decomposition would need an offset outside
/// `[-(K_C-1), 0]` — i.e. the padding is too small for a uniform-K_C
/// conversion (never the case for the paper's configs).
pub fn phase_taps_1d(k: usize, s: usize, p: usize, phase: usize) -> PhaseTaps {
    assert!(phase < s);
    assert!(p <= k - 1, "padding must satisfy P <= K-1");
    let pad = k - 1 - p;
    let t0 = (pad as isize - phase as isize).rem_euclid(s as isize) as usize;
    let kc_ = kc(k, s);
    let n_real = if t0 >= k { 0 } else { (k - t0).div_ceil(s) };
    assert!(n_real <= kc_);
    if n_real == 0 {
        // degenerate phase: every tap is implicit zero-pad, so the offset
        // derivation below is vacuous (and its range assert would fire).
        return PhaseTaps { taps: vec![None; kc_], d0: 0 };
    }
    let num = phase as isize + t0 as isize - pad as isize;
    assert_eq!(num.rem_euclid(s as isize), 0);
    let d0 = num / s as isize;
    assert!(
        (-(kc_ as isize - 1)..=0).contains(&d0),
        "TDC offset {d0} out of range for K={k} S={s} P={p}"
    );
    let taps = (0..kc_)
        .map(|u| if u < n_real { Some(s * u + t0) } else { None })
        .collect();
    PhaseTaps { taps, d0 }
}

/// One phase of the 2D decomposition: a K_C x K_C correlation filter bank
/// plus its (d0y, d0x) input offset and structural support (r_y, r_x).
/// Generic over the element precision (defaults to the f64 reference tier;
/// plan lowering casts whole phases with [`PhaseFilter::cast_to`]).
#[derive(Clone, Debug)]
pub struct PhaseFilter<E: Elem = f64> {
    pub g: Filter4<E>,
    pub d0y: isize,
    pub d0x: isize,
    /// real taps per dim — drives the Winograd sparsity case (Fig. 3/6)
    pub ry: usize,
    pub rx: usize,
}

impl<E: Elem> PhaseFilter<E> {
    /// The same phase filter at another precision (taps converted
    /// elementwise; offsets and structural support are precision-free).
    pub fn cast_to<T: Elem>(&self) -> PhaseFilter<T> {
        PhaseFilter {
            g: self.g.cast_to(),
            d0y: self.d0y,
            d0x: self.d0x,
            ry: self.ry,
            rx: self.rx,
        }
    }
}

/// Full TDC decomposition: S^2 phase filters, row-major over (p_y, p_x).
/// Pure tap selection — no arithmetic — so it is exact at any precision.
pub fn decompose<E: Elem>(w: &Filter4<E>, s: usize, p: usize) -> Vec<PhaseFilter<E>> {
    assert_eq!(w.kh, w.kw, "square kernels only");
    let k = w.kh;
    let kc_ = kc(k, s);
    let mut phases = Vec::with_capacity(s * s);
    for py in 0..s {
        let ty = phase_taps_1d(k, s, p, py);
        for px in 0..s {
            let tx = phase_taps_1d(k, s, p, px);
            let mut g = Filter4::zeros(w.c_in, w.c_out, kc_, kc_);
            for (uy, t_y) in ty.taps.iter().enumerate() {
                let Some(t_y) = t_y else { continue };
                for (ux, t_x) in tx.taps.iter().enumerate() {
                    let Some(t_x) = t_x else { continue };
                    // flipped kernel: wf[t] = w[K-1-t]
                    let ky = k - 1 - t_y;
                    let kx = k - 1 - t_x;
                    for ci in 0..w.c_in {
                        for co in 0..w.c_out {
                            *g.at_mut(ci, co, uy, ux) = w.at(ci, co, ky, kx);
                        }
                    }
                }
            }
            phases.push(PhaseFilter {
                g,
                d0y: ty.d0,
                d0x: tx.d0,
                ry: ty.real_taps(),
                rx: tx.real_taps(),
            });
        }
    }
    phases
}

/// Standard DeConv by direct scatter-add (paper Fig. 2a). Reference for
/// everything else.
pub fn deconv_naive<E: Elem>(x: &Tensor3<E>, w: &Filter4<E>, s: usize, p: usize) -> Tensor3<E> {
    assert_eq!(x.c, w.c_in);
    let k = w.kh;
    let (ho, wo) = (s * x.h, s * x.w);
    let mut y = Tensor3::zeros(w.c_out, ho, wo);
    for ci in 0..x.c {
        for iy in 0..x.h {
            for ix in 0..x.w {
                // (multiply-by-zero inputs would be correct to skip; the
                // reference keeps every product for clarity)
                let v = x.at(ci, iy, ix);
                for ky in 0..k {
                    for kx in 0..k {
                        let oy = (s * iy + ky) as isize - p as isize;
                        let ox = (s * ix + kx) as isize - p as isize;
                        if oy >= 0 && (oy as usize) < ho && ox >= 0 && (ox as usize) < wo {
                            for co in 0..w.c_out {
                                *y.at_mut(co, oy as usize, ox as usize) +=
                                    v * w.at(ci, co, ky, kx);
                            }
                        }
                    }
                }
            }
        }
    }
    y
}

/// Standard strided conv (correlation semantics) with symmetric zero
/// padding `p`: the reference datapath for the zoo's encoder Conv layers
/// (DiscoGAN). Output is `[C_out, (H+2P-K)/S+1, (W+2P-K)/S+1]`.
pub fn conv2d<E: Elem>(x: &Tensor3<E>, w: &Filter4<E>, s: usize, p: usize) -> Tensor3<E> {
    assert_eq!(x.c, w.c_in);
    let k = w.kh;
    assert!(x.h + 2 * p >= k && x.w + 2 * p >= k, "conv input smaller than kernel");
    let ho = (x.h + 2 * p - k) / s + 1;
    let wo = (x.w + 2 * p - k) / s + 1;
    let xp = x.pad(p, p, p, p);
    let mut y = Tensor3::zeros(w.c_out, ho, wo);
    for co in 0..w.c_out {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = E::ZERO;
                for ci in 0..xp.c {
                    for ky in 0..k {
                        for kx in 0..k {
                            acc += xp.at(ci, s * oy + ky, s * ox + kx) * w.at(ci, co, ky, kx);
                        }
                    }
                }
                *y.at_mut(co, oy, ox) = acc;
            }
        }
    }
    y
}

/// Multi-channel valid correlation: `x[C_in,H,W] * g[C_in,C_out,K,K]`.
pub fn correlate_valid<E: Elem>(x: &Tensor3<E>, g: &Filter4<E>) -> Tensor3<E> {
    assert_eq!(x.c, g.c_in);
    let (ho, wo) = (x.h + 1 - g.kh, x.w + 1 - g.kw);
    let mut y = Tensor3::zeros(g.c_out, ho, wo);
    for co in 0..g.c_out {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = E::ZERO;
                for ci in 0..x.c {
                    for ky in 0..g.kh {
                        for kx in 0..g.kw {
                            acc += x.at(ci, oy + ky, ox + kx) * g.at(ci, co, ky, kx);
                        }
                    }
                }
                *y.at_mut(co, oy, ox) = acc;
            }
        }
    }
    y
}

/// Pad `x` so a valid K_C-tap correlation for phase offset (d0y, d0x)
/// produces exactly H x W outputs.
pub fn phase_pad<E: Elem>(x: &Tensor3<E>, d0y: isize, d0x: isize, kc_: usize) -> Tensor3<E> {
    let mut out = Tensor3::zeros(0, 0, 0);
    phase_pad_into(x, d0y, d0x, kc_, &mut out);
    out
}

/// [`phase_pad`] into a caller-owned scratch tensor (bit-identical
/// contents, no fresh allocation once the scratch has grown to the layer's
/// padded geometry). The execution engine reuses one scratch across every
/// phase and layer of a run.
pub fn phase_pad_into<E: Elem>(
    x: &Tensor3<E>,
    d0y: isize,
    d0x: isize,
    kc_: usize,
    out: &mut Tensor3<E>,
) {
    let ly = (-d0y) as usize;
    let lx = (-d0x) as usize;
    let ry = (kc_ as isize - 1 + d0y) as usize;
    let rx = (kc_ as isize - 1 + d0x) as usize;
    x.pad_into(ly, ry, lx, rx, out);
}

/// DeConv via the TDC method: S^2 valid correlations, phase-interleaved.
/// Identical function to [`deconv_naive`] (the Fig. 2 equivalence).
pub fn tdc_deconv<E: Elem>(x: &Tensor3<E>, w: &Filter4<E>, s: usize, p: usize) -> Tensor3<E> {
    let k = w.kh;
    let kc_ = kc(k, s);
    let phases = decompose(w, s, p);
    let mut y = Tensor3::zeros(w.c_out, s * x.h, s * x.w);
    for (idx, ph) in phases.iter().enumerate() {
        let (py, px) = (idx / s, idx % s);
        let xp = phase_pad(x, ph.d0y, ph.d0x, kc_);
        let yp = correlate_valid(&xp, &ph.g);
        debug_assert_eq!((yp.h, yp.w), (x.h, x.w));
        for co in 0..w.c_out {
            for iy in 0..x.h {
                for ix in 0..x.w {
                    *y.at_mut(co, s * iy + py, s * ix + px) = yp.at(co, iy, ix);
                }
            }
        }
    }
    y
}

/// Zero-padded DeConv baseline (Fig. 1b): dilate input, border-pad, conv
/// with the flipped filter. Same function; the baseline accelerator models
/// this computation including the wasted zero multiplications.
pub fn zero_padded_deconv<E: Elem>(
    x: &Tensor3<E>,
    w: &Filter4<E>,
    s: usize,
    p: usize,
) -> Tensor3<E> {
    let k = w.kh;
    assert!(p <= k - 1);
    let pad = k - 1 - p; // left/top border
    let rpad = s + p - 1; // right/bottom border (covers the output_padding region)
    // dilated + padded input: size = S*(H-1)+1 + pad + rpad = S*H + K - 1,
    // so the valid correlation below yields exactly S*H outputs.
    let hd = s * (x.h - 1) + 1 + pad + rpad;
    let wd = s * (x.w - 1) + 1 + pad + rpad;
    let mut xd = Tensor3::zeros(x.c, hd, wd);
    for c in 0..x.c {
        for iy in 0..x.h {
            for ix in 0..x.w {
                *xd.at_mut(c, pad + s * iy, pad + s * ix) = x.at(c, iy, ix);
            }
        }
    }
    // flipped filter as a correlation bank
    let mut g = Filter4::zeros(w.c_in, w.c_out, k, k);
    for ci in 0..w.c_in {
        for co in 0..w.c_out {
            for ky in 0..k {
                for kx in 0..k {
                    *g.at_mut(ci, co, ky, kx) = w.at(ci, co, k - 1 - ky, k - 1 - kx);
                }
            }
        }
    }
    let y = correlate_valid(&xd, &g);
    debug_assert_eq!((y.h, y.w), (s * x.h, s * x.w));
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_tensor(rng: &mut Rng, c: usize, h: usize, w: usize) -> Tensor3 {
        Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w))
    }

    fn rand_filter(rng: &mut Rng, ci: usize, co: usize, k: usize) -> Filter4 {
        Filter4::from_vec(ci, co, k, k, rng.normal_vec(ci * co * k * k))
    }

    #[test]
    fn kc_matches_table1() {
        assert_eq!(kc(5, 2), 3);
        assert_eq!(kc(4, 2), 2);
        assert_eq!(kc(3, 1), 3);
    }

    #[test]
    fn default_paddings() {
        assert_eq!(default_padding(5, 2), 2);
        assert_eq!(default_padding(4, 2), 1);
        assert_eq!(default_padding(3, 1), 1);
    }

    #[test]
    fn phase_taps_k5s2() {
        // K=5, S=2, P=2: phase 0 -> 3 real taps, phase 1 -> 2 real taps.
        let t0 = phase_taps_1d(5, 2, 2, 0);
        let t1 = phase_taps_1d(5, 2, 2, 1);
        assert_eq!(t0.real_taps(), 3);
        assert_eq!(t1.real_taps(), 2);
        assert_eq!(t0.d0, -1);
        assert_eq!(t1.d0, 0);
    }

    #[test]
    fn phase_taps_k4s2_all_two_tap() {
        for phase in 0..2 {
            let t = phase_taps_1d(4, 2, 1, phase);
            assert_eq!(t.real_taps(), 2, "phase {phase}");
        }
    }

    #[test]
    fn tdc_equals_naive_all_paper_configs() {
        let mut rng = Rng::new(100);
        for &(k, s) in &[(5, 2), (4, 2), (3, 1)] {
            let p = default_padding(k, s);
            let x = rand_tensor(&mut rng, 3, 5, 7);
            let w = rand_filter(&mut rng, 3, 2, k);
            let y0 = deconv_naive(&x, &w, s, p);
            let y1 = tdc_deconv(&x, &w, s, p);
            assert!(y0.max_abs_diff(&y1) < 1e-12, "K={k} S={s}");
        }
    }

    #[test]
    fn zero_padded_equals_naive() {
        let mut rng = Rng::new(101);
        for &(k, s) in &[(5, 2), (4, 2), (3, 1)] {
            let p = default_padding(k, s);
            let x = rand_tensor(&mut rng, 2, 4, 6);
            let w = rand_filter(&mut rng, 2, 3, k);
            let y0 = deconv_naive(&x, &w, s, p);
            let y1 = zero_padded_deconv(&x, &w, s, p);
            assert!(y0.max_abs_diff(&y1) < 1e-12, "K={k} S={s}");
        }
    }

    #[test]
    fn stride3_also_works() {
        // beyond the paper's configs: K=6, S=3, P=2 satisfies the offset bound
        let mut rng = Rng::new(102);
        let (k, s, p) = (6, 3, 2);
        let x = rand_tensor(&mut rng, 2, 4, 4);
        let w = rand_filter(&mut rng, 2, 2, k);
        let y0 = deconv_naive(&x, &w, s, p);
        let y1 = tdc_deconv(&x, &w, s, p);
        assert!(y0.max_abs_diff(&y1) < 1e-12);
    }

    #[test]
    fn degenerate_phase_gets_zero_real_taps() {
        // K=1, S=2, P=0: phase 1's first candidate tap (t0 = 1) is past the
        // kernel, so the phase has no real taps. Before the fix this path
        // panicked on the d0 range assert; now it returns an all-padded plan.
        let t1 = phase_taps_1d(1, 2, 0, 1);
        assert_eq!(t1.real_taps(), 0);
        assert_eq!(t1.taps, vec![None]);
        assert_eq!(t1.d0, 0);
        let t0 = phase_taps_1d(1, 2, 0, 0);
        assert_eq!(t0.real_taps(), 1);
        // decompose marks the degenerate phases and the end-to-end TDC
        // result still matches the naive scatter-add reference
        let mut rng = Rng::new(104);
        let x = rand_tensor(&mut rng, 2, 3, 4);
        let w = rand_filter(&mut rng, 2, 3, 1);
        let phases = decompose(&w, 2, 0);
        let supports: Vec<(usize, usize)> = phases.iter().map(|p| (p.ry, p.rx)).collect();
        assert_eq!(supports, vec![(1, 1), (1, 0), (0, 1), (0, 0)]);
        let y0 = deconv_naive(&x, &w, 2, 0);
        let y1 = tdc_deconv(&x, &w, 2, 0);
        assert!(y0.max_abs_diff(&y1) < 1e-12);
    }

    #[test]
    fn decompose_structural_support() {
        let mut rng = Rng::new(103);
        let w = rand_filter(&mut rng, 1, 1, 5);
        let phases = decompose(&w, 2, 2);
        let supports: Vec<(usize, usize)> = phases.iter().map(|p| (p.ry, p.rx)).collect();
        assert_eq!(supports, vec![(3, 3), (3, 2), (2, 3), (2, 2)]);
    }
}
