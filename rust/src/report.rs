//! Rendered reproductions of every table and figure in the paper's
//! evaluation. Shared by the CLI (`wingan tables ...`), the benches, and
//! EXPERIMENTS.md generation — one source of truth for the numbers.

use crate::accel::{simulate_model, AccelConfig};
use crate::dse;
use crate::energy::{fig9_row, EnergyParams};
use crate::gan::workload::{fig4_row, Method};
use crate::gan::zoo::{self, Scale};
use crate::resource;

/// Table I: GAN model descriptions.
pub fn table1() -> String {
    zoo::table1()
}

/// Fig. 4: total number of (reduced) multiplications in DeConv layers.
pub fn fig4() -> String {
    let mut out = String::from(
        "Fig. 4 — DeConv multiplications per model (G-ops, lower is better)\n\
         model      zero-padded   TDC        Winograd   ZP/Win  TDC/Win\n",
    );
    for g in zoo::all(Scale::Paper) {
        let (zp, td, wi) = fig4_row(&g);
        out += &format!(
            "{:<10} {:<13.2} {:<10.2} {:<10.2} {:<7.2} {:<7.2}\n",
            g.name,
            zp as f64 / 1e9,
            td as f64 / 1e9,
            wi as f64 / 1e9,
            zp as f64 / wi as f64,
            td as f64 / wi as f64
        );
    }
    out += "paper: DCGAN mult ratio ZP/Win 'up to 8.16x' (sec. V.C)\n";
    out
}

/// Fig. 8: performance comparison (speedup over baselines).
pub fn fig8(cfg: &AccelConfig) -> String {
    let mut out = String::from(
        "Fig. 8 — DeConv performance (cycle simulator, 100 MHz, 4 GB/s)\n\
         model      t_zp(ms)  t_tdc(ms)  t_win(ms)  ZP/Win  TDC/Win  GOP/s(win)\n",
    );
    for g in zoo::all(Scale::Paper) {
        let zp = simulate_model(&g, Method::ZeroPadded, cfg, true);
        let td = simulate_model(&g, Method::Tdc, cfg, true);
        let wi = simulate_model(&g, Method::Winograd, cfg, true);
        out += &format!(
            "{:<10} {:<9.3} {:<10.3} {:<10.3} {:<7.2} {:<8.2} {:<9.1}\n",
            g.name,
            zp.t_total * 1e3,
            td.t_total * 1e3,
            wi.t_total * 1e3,
            zp.t_total / wi.t_total,
            td.t_total / wi.t_total,
            wi.effective_gops(&g, true),
        );
    }
    out += "paper: DCGAN 8.38x/2.85x, ArtGAN 7.5x/1.78x, DiscoGAN & GP-GAN 7.15x/1.85x\n";
    out
}

/// Fig. 9: energy consumption relative to the zero-padded baseline.
pub fn fig9(cfg: &AccelConfig, ep: &EnergyParams) -> String {
    let mut out = String::from(
        "Fig. 9 — DeConv energy (per-event model; savings vs baselines)\n\
         model      E_zp(mJ)  E_tdc(mJ)  E_win(mJ)  ZP/Win  TDC/Win\n",
    );
    let models = zoo::all(Scale::Paper);
    let (mut sum_zp, mut sum_td) = (0.0, 0.0);
    for g in &models {
        let r = fig9_row(g, cfg, ep);
        sum_zp += r.saving_vs_zp();
        sum_td += r.saving_vs_tdc();
        out += &format!(
            "{:<10} {:<9.3} {:<10.3} {:<10.3} {:<7.2} {:<7.2}\n",
            g.name,
            r.e_zero_padded * 1e3,
            r.e_tdc * 1e3,
            r.e_winograd * 1e3,
            r.saving_vs_zp(),
            r.saving_vs_tdc()
        );
    }
    out += &format!(
        "mean       {:<41} {:<7.2} {:<7.2}\n",
        "",
        sum_zp / models.len() as f64,
        sum_td / models.len() as f64
    );
    out += "paper: mean 3.65x vs zero-padded, 1.74x vs TDC\n";
    out
}

/// Table II: resource utilisation for DCGAN.
pub fn table2(cfg: &AccelConfig) -> String {
    let g = zoo::dcgan(Scale::Paper);
    let ours = resource::report(&g, cfg, Method::Winograd);
    let tdc = resource::report(&g, cfg, Method::Tdc);
    let p14 = resource::PAPER_TABLE2_TDC;
    let pours = resource::PAPER_TABLE2_OURS;
    let mut out = String::from(
        "Table II — resource utilisation for DCGAN (model vs paper)\n\
         design              BRAM18K  DSP48E  LUT      FFs\n",
    );
    out += &format!(
        "[14] (model)        {:<8} {:<7} {:<8} {:<8}\n",
        tdc.bram18k, tdc.dsp48e, tdc.lut, tdc.ff
    );
    out += &format!(
        "[14] (paper)        {:<8} {:<7} {:<8} {:<8}\n",
        p14.bram18k, p14.dsp48e, p14.lut, p14.ff
    );
    out += &format!(
        "ours (model)        {:<8} {:<7} {:<8} {:<8}\n",
        ours.bram18k, ours.dsp48e, ours.lut, ours.ff
    );
    out += &format!(
        "ours (paper)        {:<8} {:<7} {:<8} {:<8}\n",
        pours.bram18k, pours.dsp48e, pours.lut, pours.ff
    );
    out
}

/// DSE table (§IV.C roof/bandwidth pairs).
pub fn dse_table() -> String {
    let models = zoo::all(Scale::Paper);
    let pts = dse::sweep(&models, &dse::VIRTEX7_485T);
    let mut out = String::from("DSE — roof/bandwidth pairs (paper sec. IV.C)\n");
    out += &dse::render_table(&pts, 12);
    let best = dse::optimal(&models, &dse::VIRTEX7_485T);
    out += &format!(
        "selected: (T_m, T_n) = ({}, {})   [paper: (4, 128)]\n",
        best.t_m, best.t_n
    );
    out
}

/// Everything, for `wingan tables --all` / EXPERIMENTS.md.
pub fn all_tables() -> String {
    let cfg = AccelConfig::default();
    let ep = EnergyParams::default();
    format!(
        "{}\n{}\n{}\n{}\n{}",
        table1(),
        fig4(),
        fig8(&cfg),
        fig9(&cfg, &ep),
        table2(&cfg)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_render() {
        let s = all_tables();
        assert!(s.contains("DCGAN"));
        assert!(s.contains("Fig. 8"));
        assert!(s.contains("Table II"));
    }

    #[test]
    fn dse_table_selects_paper_point() {
        let s = dse_table();
        assert!(s.contains("(4, 128)"), "{s}");
    }
}
