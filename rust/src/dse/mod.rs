//! Design-space exploration (paper §IV.C, eqs. 5–9).
//!
//! Enumerates tiling factors `(T_m, T_n)` under the Virtex7-485T resource
//! envelope, evaluates the analytic bandwidth requirement (eq. 7) and
//! computational roof (eq. 9) across all layers of a model (cross-layer
//! optimisation, refs [21, 22]), and returns the Pareto set plus the
//! selected optimum. With the paper's constraints the optimiser lands on
//! the paper's choice `(T_m, T_n) = (4, 128)` — see the tests.
//!
//! The same cycle model doubles as the engine's compile-time method
//! selector: [`crate::engine::Planner`] races TDC against Winograd per
//! layer through it (`Select::Auto`), so the method decision the paper
//! made by hand happens in the plan compiler here. `wingan dse` prints
//! the sweep as the paper-style table ([`crate::report::dse_table`]).

use crate::accel::config::AccelConfig;
use crate::accel::cycle::simulate_model;
use crate::gan::workload::Method;
use crate::gan::zoo::{Gan, Kind, Layer};
use crate::resource;
use crate::util::elem::Precision;
use crate::winograd::sparsity::c_of_kc;
use crate::winograd::transforms::{M as M_TILE, N as N_TILE};

/// Virtex7-485T envelope (Xilinx DS180).
#[derive(Clone, Copy, Debug)]
pub struct Envelope {
    pub dsp48e: usize,
    pub bram18k: usize,
    pub lut: usize,
    pub ff: usize,
}

pub const VIRTEX7_485T: Envelope = Envelope {
    dsp48e: 2800,
    bram18k: 2060,
    lut: 303_600,
    ff: 607_200,
};

/// One explored design point.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub t_m: usize,
    pub t_n: usize,
    /// cross-layer (min over layers) computational roof, GOP/s (eq. 9)
    pub roof_gops: f64,
    /// model latency under the full cycle model, seconds
    pub latency: f64,
    /// peak per-layer bandwidth requirement, bytes/s (eq. 7)
    pub bandwidth_req: f64,
    pub dsp: usize,
    pub bram: usize,
    pub feasible: bool,
}

/// eq. 7: bandwidth needed so that the per-stripe transfer hides under the
/// per-stripe compute for one layer.
pub fn bandwidth_requirement(l: &Layer, cfg: &AccelConfig) -> f64 {
    if l.kind != Kind::Deconv {
        return 0.0;
    }
    let sim = crate::accel::cycle::simulate_layer(l, Method::Winograd, cfg);
    if sim.stripes == 0 || sim.t_compute <= 0.0 {
        return 0.0;
    }
    // activation bytes that must move per stripe / compute seconds per
    // stripe (weights stream on the overlapped path, as in eq. 6/7 which
    // model output data only)
    let bytes_per_stripe =
        (sim.offchip_activation_bytes as f64 / sim.stripes as f64).max(1.0);
    bytes_per_stripe / (sim.t_compute / sim.stripes as f64)
}

/// eq. 9: computational roof for one layer = total spatial work over the
/// modelled processing time (prologue + stripes * T_C).
pub fn computational_roof(l: &Layer, cfg: &AccelConfig) -> f64 {
    let s = l.s as f64;
    let r = crate::tdc::kc(l.k, l.s) as f64;
    let work = 2.0 * s * s * l.c_out as f64 * l.c_in as f64
        * l.h_in as f64 * l.w_in as f64 * r * r;
    let sim = crate::accel::cycle::simulate_layer(l, Method::Winograd, cfg);
    let t = sim.t_prologue + sim.t_compute;
    work / t / 1e9
}

/// Evaluate one `(T_m, T_n)` point against a set of models.
pub fn evaluate(t_m: usize, t_n: usize, models: &[Gan], env: &Envelope) -> DesignPoint {
    let cfg = AccelConfig::default().with_tiles(t_m, t_n);
    let mut roof = f64::INFINITY;
    let mut latency = 0.0;
    let mut bw = 0.0f64;
    for g in models {
        for l in g.deconv_layers() {
            roof = roof.min(computational_roof(l, &cfg));
            bw = bw.max(bandwidth_requirement(l, &cfg));
        }
        latency += simulate_model(g, Method::Winograd, &cfg, true).t_total;
    }
    let dsp = resource::dsp48e(&cfg);
    let bram = models
        .iter()
        .map(|g| resource::bram18k(g, &cfg, Method::Winograd))
        .max()
        .unwrap_or(0);
    let feasible = dsp <= env.dsp48e && bram <= env.bram18k;
    DesignPoint { t_m, t_n, roof_gops: roof, latency, bandwidth_req: bw, dsp, bram, feasible }
}

/// Sweep power-of-two tilings under the envelope; returns all points
/// (feasible and not), sorted by latency among feasible first.
pub fn sweep(models: &[Gan], env: &Envelope) -> Vec<DesignPoint> {
    let mut points = Vec::new();
    for log_m in 0..=6 {
        for log_n in 3..=9 {
            let (t_m, t_n) = (1usize << log_m, 1usize << log_n);
            if t_m * t_n > 4096 {
                continue;
            }
            points.push(evaluate(t_m, t_n, models, env));
        }
    }
    // the paper selects by the roofline method [21, 22]: maximise the
    // cross-layer computational roof, break ties with the lower bandwidth
    // requirement (a roof that needs less memory headroom), then deeper
    // channel tiling. Latency under the full cycle model is reported for
    // comparison but is not the selection objective.
    points.sort_by(|a, b| {
        b.feasible
            .cmp(&a.feasible)
            .then(b.roof_gops.partial_cmp(&a.roof_gops).unwrap())
            .then(a.bandwidth_req.partial_cmp(&b.bandwidth_req).unwrap())
            .then(b.t_n.cmp(&a.t_n))
    });
    points
}

/// The selected optimum: highest cross-layer roof among feasible points
/// (ties -> lower bandwidth requirement, then larger T_n).
pub fn optimal(models: &[Gan], env: &Envelope) -> DesignPoint {
    sweep(models, env).into_iter().find(|p| p.feasible).expect("no feasible design point")
}

/// Render the DSE table (roof/bandwidth pairs, paper §IV.C).
pub fn render_table(points: &[DesignPoint], top: usize) -> String {
    let mut out = String::from(
        "T_m  T_n   DSP   BRAM  roof(GOP/s)  BW-req(GB/s)  latency(ms)  feasible\n",
    );
    for p in points.iter().take(top) {
        out += &format!(
            "{:<4} {:<5} {:<5} {:<5} {:<12.1} {:<13.2} {:<12.3} {}\n",
            p.t_m,
            p.t_n,
            p.dsp,
            p.bram,
            p.roof_gops,
            p.bandwidth_req / 1e9,
            p.latency * 1e3,
            p.feasible
        );
    }
    out
}

/// Per-model serving-precision recommendation — the eq. 7 bandwidth
/// analysis applied to the precision/resource trade-off the FPGA
/// methodology papers make explicit (Ahmad & Pasha 1903.01811, Alhussain
/// 2201.06878): reduced precision halves the bytes behind every word the
/// datapath moves.
///
/// The rule: evaluate each deconv layer's eq. 7 bandwidth requirement with
/// the word width doubled to the f64 reference tier's 8 bytes. If any
/// layer then *needs more bandwidth than the envelope provides* — i.e. the
/// full-precision tier would be transfer-bound somewhere — recommend
/// [`Precision::F32`], which halves the transfer volume and converts the
/// saved bandwidth directly into throughput. A model whose every layer
/// hides its transfers under compute even at 8-byte words keeps the
/// [`Precision::F64`] reference tier: it has no bandwidth to reclaim.
///
/// This is [`crate::engine::Planner::resolve_precision`]'s `Auto` policy;
/// `wingan serve --precision` / `WINGAN_PRECISION` /
/// `NativeConfig::precision` override it end to end.
pub fn recommend_precision(g: &Gan, cfg: &AccelConfig) -> Precision {
    let f64_words = AccelConfig { word_bytes: Precision::F64.word_bytes(), ..*cfg };
    for l in g.deconv_layers() {
        if bandwidth_requirement(l, &f64_words) > cfg.bandwidth {
            return Precision::F32;
        }
    }
    Precision::F64
}

/// Per-host GEMM micro-kernel recommendation — the third leg of the
/// compile-time race next to the method and precision selections: the
/// explicit SIMD kernel executes the identical IEEE operation sequence as
/// the blocked scalar loop (see [`crate::winograd::kernel`]), so whenever
/// the host has the instruction set there is no accuracy trade-off and the
/// wider datapath wins outright.
///
/// This is [`crate::engine::Planner::resolve_kernel`]'s `Auto` policy;
/// `wingan serve --kernel` / `WINGAN_KERNEL` / `NativeConfig::kernel`
/// override it end to end.
pub fn recommend_kernel() -> crate::winograd::kernel::KernelKind {
    use crate::winograd::kernel::{simd_available, KernelKind};
    if simd_available() {
        KernelKind::Simd
    } else {
        KernelKind::Scalar
    }
}

/// The paper's eq. 5 `C(K_C)/m^2` cycles-per-output constant, exposed for
/// the docs/benches.
pub fn eq5_constant(k: usize, s: usize, p: usize) -> f64 {
    c_of_kc(k, s, p) as f64 / (M_TILE * M_TILE) as f64
}

/// Input-tile footprint per stripe (for VMEM/BRAM sizing discussions).
pub fn stripe_input_words(l: &Layer, t_n: usize) -> usize {
    (N_TILE + M_TILE) * l.w_in * t_n.min(l.c_in)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gan::zoo::{self, Scale};

    #[test]
    fn optimal_matches_paper_choice() {
        // Cross-layer DSE over the four GANs under the 485T envelope picks
        // the paper's (T_m, T_n) = (4, 128).
        let models = zoo::all(Scale::Paper);
        let best = optimal(&models, &VIRTEX7_485T);
        assert_eq!((best.t_m, best.t_n), (4, 128), "got {best:?}");
    }

    #[test]
    fn dsp_constraint_prunes_big_tilings() {
        let models = vec![zoo::dcgan(Scale::Paper)];
        let pts = sweep(&models, &VIRTEX7_485T);
        for p in &pts {
            if p.t_m * p.t_n > 560 {
                assert!(!p.feasible, "({}, {}) should exceed 2800 DSPs", p.t_m, p.t_n);
            }
        }
    }

    #[test]
    fn roof_increases_with_parallelism_until_ceil_waste() {
        let models = vec![zoo::dcgan(Scale::Paper)];
        let p64 = evaluate(4, 64, &models, &VIRTEX7_485T);
        let p128 = evaluate(4, 128, &models, &VIRTEX7_485T);
        assert!(p128.roof_gops > p64.roof_gops);
    }

    #[test]
    fn eq5_constants() {
        assert_eq!(eq5_constant(5, 2, 2), 49.0 / 4.0);
        assert_eq!(eq5_constant(4, 2, 1), 9.0);
    }

    #[test]
    fn bandwidth_requirement_positive_for_deconv() {
        let g = zoo::dcgan(Scale::Paper);
        let cfg = AccelConfig::default();
        for l in g.deconv_layers() {
            assert!(bandwidth_requirement(l, &cfg) > 0.0);
        }
    }

    #[test]
    fn precision_recommendation_follows_bandwidth_envelope() {
        use crate::util::elem::Precision;
        let g = zoo::dcgan(Scale::Paper);
        // a starved envelope is transfer-bound everywhere -> f32 tier
        let starved = AccelConfig::default().with_bandwidth(1.0);
        assert_eq!(recommend_precision(&g, &starved), Precision::F32);
        // an effectively infinite envelope hides every transfer -> the
        // f64 reference tier (nothing to reclaim)
        let lavish = AccelConfig::default().with_bandwidth(1e30);
        assert_eq!(recommend_precision(&g, &lavish), Precision::F64);
        // deterministic at any fixed config
        let cfg = AccelConfig::default();
        assert_eq!(recommend_precision(&g, &cfg), recommend_precision(&g, &cfg));
    }

    #[test]
    fn kernel_recommendation_matches_host_capability() {
        use crate::winograd::kernel::{simd_available, KernelKind};
        let want = if simd_available() { KernelKind::Simd } else { KernelKind::Scalar };
        assert_eq!(recommend_kernel(), want);
        assert_eq!(recommend_kernel(), recommend_kernel(), "deterministic");
    }
}
