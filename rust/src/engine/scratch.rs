//! Per-worker scratch arenas for the execution hot loop.
//!
//! The PR-2 engine allocated on every tile of the Winograd datapath (a
//! fresh `ReorderedTile` per tile, a fresh `Vec<Tile4>` accumulator inside
//! `engine_multiply`) and materialized a fresh phase-padded input tensor
//! per phase of every layer. A [`Scratch`] owns all of those buffers once:
//! it is checked out of a [`ScratchStash`] for the duration of one pool
//! task (or one whole run, for the dispatching thread), grown to the
//! largest geometry it has seen, and returned for the next task to reuse —
//! so the steady-state hot loop performs **zero per-tile heap
//! allocations**, across tiles, phases and layers alike.
//!
//! Scratches are generic over the plan's element precision: an `f32`
//! engine's arenas hold `f32` words (half the bytes of the reference
//! tier), and each engine's stash only ever carries scratches of its own
//! precision.
//!
//! Scratch reuse is invisible to the numerics: every buffer is either
//! fully rewritten before it is read (`v`), zeroed by the kernel that
//! fills it (`m` in [`multiply_batch`] — every dispatched micro-kernel,
//! scalar or SIMD, zero-initializes its accumulator block, and the
//! zero-skip run-lists only elide *products*, never the zeroing), or
//! zero-filled on resize (`xp` via [`Tensor3::pad_into`]).
//!
//! [`multiply_batch`]: crate::winograd::kernel::multiply_batch
//! [`Tensor3::pad_into`]: crate::util::tensor::Tensor3::pad_into
//! [`ScratchStash`]: crate::engine::pool::ScratchStash

use crate::util::elem::Elem;
use crate::util::tensor::Tensor3;
use crate::winograd::transforms::N;

/// Reusable per-task buffers for the engine's three datapaths, at element
/// precision `E`.
///
/// One `Scratch` is checked out of the engine's [`ScratchStash`] per pool
/// task and per run; its buffers only ever grow, so after the first few
/// dispatches the hot loop runs allocation-free. Fields are public so the
/// execution loops can borrow them disjointly (`v` immutably while `m` is
/// written).
///
/// [`ScratchStash`]: crate::engine::pool::ScratchStash
pub struct Scratch<E: Elem = f64> {
    /// Padded input view: the phase-padded map on the deconv datapaths, the
    /// border-padded input on the conv datapath. Owned by the dispatching
    /// side of a run and reused across every phase and layer.
    pub xp: Tensor3<E>,
    /// Gathered Winograd-domain tile matrix for one stripe, position-major
    /// `[pos][c_in][tiles_w]` over all 16 positions — the left operand
    /// gather feeding [`multiply_batch`].
    ///
    /// [`multiply_batch`]: crate::winograd::kernel::multiply_batch
    pub v: Vec<E>,
    /// Winograd-domain accumulators for one stripe, `[c_out][pos][tiles_w]`
    /// (zeroed by the batched kernel; skipped positions stay zero for the
    /// inverse transform).
    pub m: Vec<E>,
}

impl<E: Elem> Default for Scratch<E> {
    fn default() -> Self {
        Scratch { xp: Tensor3::zeros(0, 0, 0), v: Vec::new(), m: Vec::new() }
    }
}

impl<E: Elem> std::fmt::Debug for Scratch<E> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scratch")
            .field("xp_words", &self.xp.numel())
            .field("v_words", &self.v.len())
            .field("m_words", &self.m.len())
            .finish()
    }
}

impl<E: Elem> Scratch<E> {
    /// Size `v` and `m` for one Winograd stripe of `tiles` tiles at
    /// `c_in`/`c_out` channels. Shrinks/grows the *length* to the exact
    /// stripe geometry (the batched kernel asserts it) while the underlying
    /// capacity only ever grows — no reallocation once warm. Contents are
    /// not cleared: `v` is fully rewritten by the gather and `m` is zeroed
    /// by the kernel.
    pub fn ensure_winograd(&mut self, c_in: usize, c_out: usize, tiles: usize) {
        self.v.resize(N * N * c_in * tiles, E::ZERO);
        self.m.resize(c_out * N * N * tiles, E::ZERO);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_winograd_sizes_exactly_and_keeps_capacity() {
        let mut s: Scratch = Scratch::default();
        s.ensure_winograd(8, 4, 6);
        assert_eq!(s.v.len(), N * N * 8 * 6);
        assert_eq!(s.m.len(), 4 * N * N * 6);
        let cap_v = s.v.capacity();
        // smaller geometry: exact length, no reallocation
        s.ensure_winograd(2, 1, 3);
        assert_eq!(s.v.len(), N * N * 2 * 3);
        assert_eq!(s.m.len(), N * N * 3);
        assert!(s.v.capacity() >= cap_v);
    }

    #[test]
    fn f32_scratch_same_geometry_half_the_bytes() {
        let mut s: Scratch<f32> = Scratch::default();
        s.ensure_winograd(8, 4, 6);
        assert_eq!(s.v.len(), N * N * 8 * 6);
        assert_eq!(std::mem::size_of_val(&s.v[..]) * 2, N * N * 8 * 6 * 8);
    }
}
