//! Native serving backend: compiled [`AnyEngine`]s behind the
//! coordinator's artifact-manifest contract.
//!
//! The PJRT runtime is gated off in this build (see `runtime::client`), so
//! the serving path executes generation requests on the pure-rust engine:
//! a synthetic [`Manifest`] advertises the same `(model, method, batch)`
//! routes the AOT artifacts would, and [`NativeRuntime::execute`] unpacks a
//! packed batch buffer, runs each sample through the precompiled plan, and
//! repacks f32 outputs. Route methods:
//!
//! * `"winograd"` — plans compiled with [`Select::Auto`] (the fast
//!   algorithm wherever the DSE race picks it), served at the **resolved
//!   precision tier**: [`NativeConfig::precision`] wins, then the
//!   `WINGAN_PRECISION` environment variable, then the per-model `dse`
//!   recommendation ([`crate::dse::recommend_precision`]). At
//!   [`Precision::F32`] the route is the end-to-end single-precision fast
//!   path — request buffers are never widened to f64.
//! * `"tdc"` — plans forced to the TDC datapath, always served at
//!   [`Precision::F64`]: arithmetic bit-identical to the layer-composed
//!   standard-DeConv reference. This is the A/B anchor — a stable
//!   full-precision reference tier to diff any fast route (including an
//!   f32 one) against.

use std::collections::{btree_map, BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::accel::functional::Events;
use crate::engine::exec::AnyEngine;
use crate::engine::plan::{resolve_precision, PlanOptions, Planner, Select};
use crate::engine::pool::{resolve_workers, WorkerPool};
use crate::gan::workload::Method;
use crate::gan::zoo::{self, Scale};
use crate::runtime::{ArtifactEntry, Manifest};
use crate::util::elem::Precision;

/// Configuration for the native serving backend.
#[derive(Clone, Debug)]
pub struct NativeConfig {
    /// zoo scale the engines are compiled at
    pub scale: Scale,
    /// batch buckets advertised per route (ascending)
    pub buckets: Vec<usize>,
    /// worker threads in the one pool shared by every route's engine
    /// (0 = resolve via [`resolve_workers`]: `WINGAN_WORKERS`, then cores)
    pub workers: usize,
    /// weight seed (deterministic per model)
    pub seed: u64,
    /// restrict to these lowercase model ids (None = all four zoo models)
    pub models: Option<Vec<String>>,
    /// serving precision for the fast ("winograd") routes: `Some(p)`
    /// forces a tier, `None` resolves via the `WINGAN_PRECISION`
    /// environment variable and then the per-model `dse` recommendation
    /// ([`crate::engine::plan::resolve_precision`]). The `"tdc"` reference
    /// routes always serve f64 regardless.
    pub precision: Option<Precision>,
}

impl Default for NativeConfig {
    fn default() -> Self {
        NativeConfig {
            scale: Scale::Small,
            buckets: vec![1, 2, 4, 8],
            workers: 0,
            seed: 42,
            models: None,
            precision: None,
        }
    }
}

/// Route id for a zoo model name, matching the ids `python/compile/aot.py`
/// uses in the PJRT artifact manifest ("GP-GAN" -> "gpgan") so the same
/// `--model` filter works on either backend.
pub fn model_id(name: &str) -> String {
    name.chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .map(|c| c.to_ascii_lowercase())
        .collect()
}

const METHODS: [(&str, Select); 2] =
    [("winograd", Select::Auto), ("tdc", Select::Force(Method::Tdc))];

/// Build the synthetic manifest describing the native routes — the same
/// contract `python/compile/aot.py` writes for the PJRT path, with no files
/// behind it.
pub fn native_manifest(cfg: &NativeConfig) -> Manifest {
    let mut entries = Vec::new();
    for g in zoo::all(cfg.scale) {
        let id = model_id(g.name);
        if let Some(allow) = &cfg.models {
            if !allow.contains(&id) {
                continue;
            }
        }
        let first = &g.layers[0];
        let last = g.layers.last().unwrap();
        for (method, _) in METHODS {
            for &b in &cfg.buckets {
                entries.push(ArtifactEntry {
                    name: format!("{id}_{method}_b{b}"),
                    kind: "generator".into(),
                    model: id.clone(),
                    method: method.into(),
                    batch: b,
                    hlo: PathBuf::new(),
                    input_shape: vec![b, first.c_in, first.h_in, first.w_in],
                    output_shape: vec![b, last.c_out, last.h_out(), last.w_out()],
                    golden_input: PathBuf::new(),
                    golden_output: PathBuf::new(),
                });
            }
        }
    }
    Manifest {
        dir: PathBuf::new(),
        scale: format!("{:?}", cfg.scale).to_ascii_lowercase(),
        entries,
    }
}

/// The native execution backend: one compiled [`AnyEngine`] per
/// `(model, method)` route plus the manifest entries for shape checking.
/// All engines dispatch to **one persistent [`WorkerPool`]**, spawned once
/// in [`NativeRuntime::build`] — the request path never creates threads.
pub struct NativeRuntime {
    engines: BTreeMap<(String, String), AnyEngine>,
    entries: HashMap<String, ArtifactEntry>,
    /// the one pool every route's engine executes on
    pool: Arc<WorkerPool>,
    /// cumulative events across every executed sample (observability; the
    /// e2e tests assert monotone growth with batch size)
    events: Arc<Mutex<Events>>,
}

impl NativeRuntime {
    /// Compile every advertised route's plan — once, in f64 — lower each
    /// fast route to its resolved precision tier, and spawn the shared
    /// worker pool. This is the expensive, once-per-startup step (the
    /// coordinator runs it on the engine thread before reporting ready,
    /// like PJRT artifact compilation). The engine set is derived from the
    /// manifest itself, so routes and engines can never desynchronize.
    pub fn build(cfg: &NativeConfig) -> NativeRuntime {
        let manifest = native_manifest(cfg);
        let pool = WorkerPool::shared(resolve_workers(cfg.workers));
        let zoo_models = zoo::all(cfg.scale);
        // explicit config > WINGAN_PRECISION env > per-model dse Auto
        let precision_policy = resolve_precision(cfg.precision);
        let mut engines: BTreeMap<(String, String), AnyEngine> = BTreeMap::new();
        for e in &manifest.entries {
            let key = (e.model.clone(), e.method.clone());
            // one engine serves every batch bucket of a route
            if let btree_map::Entry::Vacant(slot) = engines.entry(key) {
                let g = zoo_models
                    .iter()
                    .find(|g| model_id(g.name) == e.model)
                    .expect("manifest route without a zoo model");
                let select = METHODS
                    .iter()
                    .find(|(m, _)| *m == e.method)
                    .expect("manifest route with unknown method")
                    .1;
                let planner = Planner::new(PlanOptions {
                    select,
                    precision: precision_policy,
                    ..Default::default()
                });
                // the tdc route is the bit-exact f64 reference anchor; fast
                // routes serve at the planner-resolved tier
                let precision = if e.method == "tdc" {
                    Precision::F64
                } else {
                    planner.resolve_precision(g)
                };
                // one Arc'd compiled f64 plan per route: every engine clone
                // (and any future co-resident engine) shares it; the f32
                // tier lowers it exactly once, at build time
                let plan = Arc::new(planner.compile_seeded(g, cfg.seed));
                slot.insert(AnyEngine::build(plan, precision, pool.clone()));
            }
        }
        let entries = manifest.entries.iter().map(|e| (e.name.clone(), e.clone())).collect();
        NativeRuntime { engines, entries, pool, events: Arc::new(Mutex::new(Events::default())) }
    }

    /// The worker pool shared by every route's engine.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Handle to the cumulative event counters (cloneable across threads).
    pub fn events_handle(&self) -> Arc<Mutex<Events>> {
        self.events.clone()
    }

    /// Snapshot of the cumulative events.
    pub fn events(&self) -> Events {
        self.events.lock().unwrap().clone()
    }

    /// The route engine for `(model, method)`, at whatever precision tier
    /// the route resolved to.
    pub fn engine(&self, model: &str, method: &str) -> Option<&AnyEngine> {
        self.engines.get(&(model.to_string(), method.to_string()))
    }

    /// Execute one packed batch buffer against a named route artifact.
    /// Mirrors the PJRT executable contract: fixed batch shape, padded
    /// slots are computed like real samples. The batch goes through
    /// [`crate::engine::Engine::run_batch`], so wide buckets parallelise
    /// across samples and narrow ones across stripes — bitwise identical
    /// either way. On an f32 route the buffer stays in single precision
    /// end to end.
    pub fn execute(&self, name: &str, input: &[f32]) -> Result<Vec<f32>, String> {
        let entry = self.entries.get(name).ok_or_else(|| format!("unknown artifact {name}"))?;
        if input.len() != entry.input_len() {
            return Err(format!(
                "artifact {name}: input length {} != expected {}",
                input.len(),
                entry.input_len()
            ));
        }
        let engine = self
            .engines
            .get(&(entry.model.clone(), entry.method.clone()))
            .ok_or_else(|| format!("no engine for route {}/{}", entry.model, entry.method))?;
        let (out, batch_events) = engine.run_packed(entry.batch, input);
        self.events.lock().unwrap().merge(&batch_events);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::PRECISION_ENV;

    fn tiny_cfg() -> NativeConfig {
        NativeConfig {
            scale: Scale::Tiny,
            buckets: vec![1, 2],
            workers: 2,
            models: Some(vec!["dcgan".into()]),
            ..Default::default()
        }
    }

    #[test]
    fn manifest_advertises_both_methods_and_buckets() {
        let m = native_manifest(&NativeConfig::default());
        // ids match python/compile/aot.py's manifest ("GP-GAN" -> "gpgan")
        assert_eq!(m.models(), vec!["artgan", "dcgan", "discogan", "gpgan"]);
        let buckets: Vec<usize> =
            m.buckets("dcgan", "winograd").iter().map(|e| e.batch).collect();
        assert_eq!(buckets, vec![1, 2, 4, 8]);
        assert!(m.find("gpgan_tdc_b4").is_some());
    }

    #[test]
    fn execute_batches_and_counts_events() {
        let rt = NativeRuntime::build(&tiny_cfg());
        let e1 = rt.entries.get("dcgan_winograd_b1").unwrap().clone();
        let out = rt.execute(&e1.name, &vec![0.5; e1.input_len()]).unwrap();
        assert_eq!(out.len(), e1.output_len());
        let after_one = rt.events().mults;
        assert!(after_one > 0);
        let e2 = rt.entries.get("dcgan_winograd_b2").unwrap().clone();
        rt.execute(&e2.name, &vec![0.5; e2.input_len()]).unwrap();
        // batch-2 adds exactly twice the single-sample work
        assert_eq!(rt.events().mults, after_one * 3);
    }

    #[test]
    fn all_routes_share_one_worker_pool() {
        let rt = NativeRuntime::build(&NativeConfig {
            scale: Scale::Tiny,
            buckets: vec![1, 2],
            workers: 2,
            ..Default::default()
        });
        let wino = rt.engine("dcgan", "winograd").unwrap();
        let tdc = rt.engine("gpgan", "tdc").unwrap();
        assert!(Arc::ptr_eq(wino.pool(), rt.pool()));
        assert!(Arc::ptr_eq(tdc.pool(), rt.pool()));
        assert_eq!(rt.pool().threads(), 2);
    }

    #[test]
    fn rejects_bad_inputs() {
        let rt = NativeRuntime::build(&tiny_cfg());
        assert!(rt.execute("nope", &[0.0; 4]).is_err());
        assert!(rt.execute("dcgan_winograd_b1", &[0.0; 4]).is_err());
    }

    #[test]
    fn winograd_and_tdc_routes_agree() {
        let rt = NativeRuntime::build(&tiny_cfg());
        let e = rt.entries.get("dcgan_winograd_b1").unwrap().clone();
        let x: Vec<f32> = (0..e.input_len()).map(|i| ((i % 13) as f32 - 6.0) / 6.0).collect();
        let a = rt.execute("dcgan_winograd_b1", &x).unwrap();
        let b = rt.execute("dcgan_tdc_b1", &x).unwrap();
        let diff = crate::util::bin::max_abs_diff(&a, &b);
        // the fast route may serve the f32 tier (Auto policy), so the A/B
        // tolerance is single-precision-accumulation sized, not 1e-4
        assert!(diff < 1e-3, "methods diverge: {diff}");
    }

    #[test]
    fn tdc_route_is_always_the_f64_reference_tier() {
        // even when the fast routes are forced to f32, the tdc anchor
        // stays full-precision
        let rt = NativeRuntime::build(&NativeConfig {
            precision: Some(Precision::F32),
            ..tiny_cfg()
        });
        assert_eq!(rt.engine("dcgan", "tdc").unwrap().precision(), Precision::F64);
        assert_eq!(rt.engine("dcgan", "winograd").unwrap().precision(), Precision::F32);
    }

    #[test]
    fn forced_precision_applies_to_fast_routes() {
        for p in [Precision::F32, Precision::F64] {
            let rt = NativeRuntime::build(&NativeConfig { precision: Some(p), ..tiny_cfg() });
            assert_eq!(rt.engine("dcgan", "winograd").unwrap().precision(), p);
            // and both tiers execute correctly end to end
            let e1 = rt.entries.get("dcgan_winograd_b1").unwrap().clone();
            let out = rt.execute(&e1.name, &vec![0.25; e1.input_len()]).unwrap();
            assert_eq!(out.len(), e1.output_len());
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn f32_route_tracks_the_f64_route() {
        let rt32 = NativeRuntime::build(&NativeConfig {
            precision: Some(Precision::F32),
            ..tiny_cfg()
        });
        let rt64 = NativeRuntime::build(&NativeConfig {
            precision: Some(Precision::F64),
            ..tiny_cfg()
        });
        let e = rt32.entries.get("dcgan_winograd_b1").unwrap().clone();
        let x: Vec<f32> = (0..e.input_len()).map(|i| ((i % 11) as f32 - 5.0) / 5.0).collect();
        let a = rt32.execute(&e.name, &x).unwrap();
        let b = rt64.execute(&e.name, &x).unwrap();
        let diff = crate::util::bin::max_abs_diff(&a, &b);
        assert!(diff < 1e-3, "f32 tier diverges from f64 tier: {diff}");
        // identical event accounting across tiers
        assert_eq!(rt32.events(), rt64.events());
    }

    #[test]
    fn env_name_is_stable() {
        // the documented override variable (exercised end-to-end by ops,
        // not mutated here: tests share one process environment)
        assert_eq!(PRECISION_ENV, "WINGAN_PRECISION");
    }
}
