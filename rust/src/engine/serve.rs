//! Native serving backend: compiled [`AnyEngine`]s behind the
//! coordinator's artifact-manifest contract.
//!
//! The PJRT runtime is gated off in this build (see `runtime::client`), so
//! the serving path executes generation requests on the pure-rust engine:
//! a synthetic [`Manifest`] advertises the same `(model, method, batch)`
//! routes the AOT artifacts would, and [`NativeRuntime::execute`] unpacks a
//! packed batch buffer, runs each sample through the precompiled plan, and
//! repacks f32 outputs. Route methods:
//!
//! * `"winograd"` — plans compiled with [`Select::Auto`] (the fast
//!   algorithm wherever the DSE race picks it), served at the **resolved
//!   precision tier**: [`NativeConfig::precision`] wins, then the
//!   `WINGAN_PRECISION` environment variable, then the per-model `dse`
//!   recommendation ([`crate::dse::recommend_precision`]). At
//!   [`Precision::F32`] the route is the end-to-end single-precision fast
//!   path — request buffers are never widened to f64.
//! * `"tdc"` — plans forced to the TDC datapath, always served at
//!   [`Precision::F64`]: arithmetic bit-identical to the layer-composed
//!   standard-DeConv reference. This is the A/B anchor — a stable
//!   full-precision reference tier to diff any fast route (including an
//!   f32 one) against.

use std::collections::{btree_map, BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::accel::functional::Events;
use crate::artifact::{AnyPlan, ArtifactError, PlanCacheStats, PlanKey, PlanStore};
use crate::engine::exec::{AnyEngine, Engine};
use crate::engine::plan::{resolve_kernel, resolve_precision, PlanOptions, Planner, Select};
use crate::engine::pool::{resolve_workers, WorkerPool};
use crate::gan::workload::Method;
use crate::gan::zoo::{self, Gan, Scale};
use crate::runtime::{ArtifactEntry, Manifest};
use crate::util::elem::Precision;

/// Configuration for the native serving backend.
#[derive(Clone, Debug)]
pub struct NativeConfig {
    /// zoo scale the engines are compiled at
    pub scale: Scale,
    /// batch buckets advertised per route (ascending)
    pub buckets: Vec<usize>,
    /// worker threads in the one pool shared by every route's engine
    /// (0 = resolve via [`resolve_workers`]: `WINGAN_WORKERS`, then cores)
    pub workers: usize,
    /// weight seed (deterministic per model)
    pub seed: u64,
    /// restrict to these lowercase model ids (None = all four zoo models)
    pub models: Option<Vec<String>>,
    /// serving precision for the fast ("winograd") routes: `Some(p)`
    /// forces a tier, `None` resolves via the `WINGAN_PRECISION`
    /// environment variable and then the per-model `dse` recommendation
    /// ([`crate::engine::plan::resolve_precision`]). The `"tdc"` reference
    /// routes always serve f64 regardless.
    pub precision: Option<Precision>,
    /// GEMM micro-kernel for Winograd-method plans: `Some(k)` forces one,
    /// `None` resolves via the `WINGAN_KERNEL` environment variable and
    /// then the host capability probe
    /// ([`crate::engine::plan::resolve_kernel`]). Forcing SIMD on a host
    /// without AVX2/NEON falls back to scalar with a logged correction.
    pub kernel: Option<crate::winograd::kernel::KernelKind>,
    /// root of an on-disk [`PlanStore`] to boot from: route plans are
    /// loaded as artifacts when present (cold start becomes a file read),
    /// and any route that misses — or finds a corrupt/mismatched artifact
    /// — falls back to in-process compilation and publishes the result.
    /// `None` compiles every route in-process, as before.
    pub plan_store: Option<PathBuf>,
    /// deterministic fault-injection plane ([`crate::faultinject`]),
    /// installed on the shared worker pool (`worker_chunk` site) and
    /// consulted at plan-store loads (`artifact_load` site). `None` in
    /// production; `wingan chaos` and the chaos tests set it.
    pub faults: Option<Arc<crate::faultinject::FaultPlane>>,
}

impl Default for NativeConfig {
    fn default() -> Self {
        NativeConfig {
            scale: Scale::Small,
            buckets: vec![1, 2, 4, 8],
            workers: 0,
            seed: 42,
            models: None,
            precision: None,
            kernel: None,
            plan_store: None,
            faults: None,
        }
    }
}

/// Route id for a zoo model name, matching the ids `python/compile/aot.py`
/// uses in the PJRT artifact manifest ("GP-GAN" -> "gpgan") so the same
/// `--model` filter works on either backend.
pub fn model_id(name: &str) -> String {
    name.chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .map(|c| c.to_ascii_lowercase())
        .collect()
}

/// The two serving route methods and the [`Select`] policy each compiles
/// with: `"winograd"` races TDC vs the fast algorithm through the DSE
/// cycle model per layer, `"tdc"` forces the bit-exact reference datapath.
/// `wingan compile` iterates exactly this table so AOT artifacts and
/// serving routes can never disagree about what a method name means.
pub const ROUTE_METHODS: [(&str, Select); 2] =
    [("winograd", Select::Auto), ("tdc", Select::Force(Method::Tdc))];

/// Build the synthetic manifest describing the native routes — the same
/// contract `python/compile/aot.py` writes for the PJRT path, with no files
/// behind it.
pub fn native_manifest(cfg: &NativeConfig) -> Manifest {
    let mut entries = Vec::new();
    for g in zoo::all(cfg.scale) {
        let id = model_id(g.name);
        if let Some(allow) = &cfg.models {
            if !allow.contains(&id) {
                continue;
            }
        }
        let first = &g.layers[0];
        let last = g.layers.last().unwrap();
        for (method, _) in ROUTE_METHODS {
            for &b in &cfg.buckets {
                entries.push(ArtifactEntry {
                    name: format!("{id}_{method}_b{b}"),
                    kind: "generator".into(),
                    model: id.clone(),
                    method: method.into(),
                    batch: b,
                    hlo: PathBuf::new(),
                    input_shape: vec![b, first.c_in, first.h_in, first.w_in],
                    output_shape: vec![b, last.c_out, last.h_out(), last.w_out()],
                    golden_input: PathBuf::new(),
                    golden_output: PathBuf::new(),
                });
            }
        }
    }
    Manifest {
        dir: PathBuf::new(),
        scale: format!("{:?}", cfg.scale).to_ascii_lowercase(),
        entries,
    }
}

/// The native execution backend: one compiled [`AnyEngine`] per
/// `(model, method)` route plus the manifest entries for shape checking.
/// All engines dispatch to **one persistent [`WorkerPool`]**, spawned once
/// in [`NativeRuntime::build`] — the request path never creates threads.
pub struct NativeRuntime {
    engines: BTreeMap<(String, String), AnyEngine>,
    entries: HashMap<String, ArtifactEntry>,
    /// the one pool every route's engine executes on
    pool: Arc<WorkerPool>,
    /// cumulative events across every executed sample (observability; the
    /// e2e tests assert monotone growth with batch size)
    events: Arc<Mutex<Events>>,
    /// warm-vs-cold startup accounting (all zeros without a plan store)
    plan_stats: PlanCacheStats,
}

/// Whether a loaded plan's layer stack matches the generator this binary's
/// zoo advertises for the route — every `Layer` field (geometry *and*
/// activation; `Layer: PartialEq` is derived so future fields are tracked
/// automatically), not just endpoint shapes, so an artifact compiled
/// against an older zoo (whose interior layers changed) can never be
/// served.
fn plan_matches_zoo<E: crate::util::elem::Elem>(plan: &ModelPlan<E>, g: &Gan) -> bool {
    plan.layers.len() == g.layers.len()
        && plan.layers.iter().zip(&g.layers).all(|(lp, l)| lp.layer == *l)
}

/// Bring up one route's engine through the plan store: artifact hit when a
/// valid artifact exists for the key, otherwise in-process compilation
/// followed by a best-effort publish so the *next* startup is warm. Every
/// load failure is typed, counted, and logged — never fatal — and a
/// corrupt or zoo-stale artifact is **quarantined** (renamed aside, see
/// [`PlanStore::quarantine`]) so later boots never re-parse known-bad
/// bytes and the poison artifact is preserved for forensics.
fn engine_via_store(
    store: &PlanStore,
    stats: &mut PlanCacheStats,
    g: &Gan,
    planner: &Planner,
    key: &PlanKey,
    pool: Arc<WorkerPool>,
    faults: Option<&crate::faultinject::FaultPlane>,
) -> AnyEngine {
    // whether a fallback compile may publish over the existing slot: true
    // for everything except a weight-seed mismatch — a different-seed
    // artifact is a valid deployment for another configuration, and
    // overwriting it would let one misconfigured server destroy (and
    // thrash) an AOT-compiled store
    let mut overwrite = true;
    // Deterministic fault hook (ArtifactLoad site): a panic here unwinds the
    // whole startup — the coordinator's boot-time containment turns it into
    // a typed error instead of a crash; an injected load error exercises the
    // exact quarantine + recompile path a corrupt artifact takes.
    let mut injected_failure = false;
    if let Some(plane) = faults {
        match plane.check(crate::faultinject::FaultSite::ArtifactLoad) {
            Some(crate::faultinject::FaultAction::Panic) => {
                panic!("fault injected: artifact_load panic")
            }
            Some(crate::faultinject::FaultAction::Delay(d)) => std::thread::sleep(d),
            Some(_) => injected_failure = true,
            None => {}
        }
    }
    let loaded = if injected_failure {
        stats.load_failures += 1;
        eprintln!(
            "plan-store: {} unusable (fault injected: artifact_load); recompiling",
            key.file_name()
        );
        if store.quarantine(key, "fault injected: artifact_load") {
            stats.quarantined += 1;
        }
        None
    } else {
        match store.load(key) {
            Ok(plan) => {
                // a decode-valid artifact must still match — layer for
                // layer — the generator this binary's zoo advertises for
                // the route: zoo geometry can change without a wire-format
                // bump, and a stale plan would serve the old architecture
                // (or panic the engine thread at request time)
                let matches = match &plan {
                    AnyPlan::F32(p) => plan_matches_zoo(p, g),
                    AnyPlan::F64(p) => plan_matches_zoo(p, g),
                };
                if matches {
                    Some(plan)
                } else {
                    stats.load_failures += 1;
                    eprintln!(
                        "plan-store: {} is stale for the current zoo; recompiling",
                        key.file_name()
                    );
                    if store.quarantine(key, "stale for the current zoo") {
                        stats.quarantined += 1;
                    }
                    None
                }
            }
            Err(err) => {
                let seed_mismatch =
                    matches!(err, ArtifactError::KeyMismatch { field: "weight seed", .. });
                if !matches!(err, ArtifactError::Missing { .. }) {
                    stats.load_failures += 1;
                    // the seed-mismatch arm below prints its own (more
                    // specific) message; don't log the same event twice
                    if !seed_mismatch {
                        eprintln!(
                            "plan-store: {} unusable ({err}); recompiling",
                            key.file_name()
                        );
                        // a seed-mismatched artifact is a *valid* plan for
                        // a different configuration, and a missing one has
                        // no bytes to preserve — only genuinely unusable
                        // bytes get moved aside
                        if store.quarantine(key, &format!("{err}")) {
                            stats.quarantined += 1;
                        }
                    }
                }
                if seed_mismatch {
                    overwrite = false;
                }
                None
            }
        }
    };
    match loaded {
        Some(AnyPlan::F32(plan)) => {
            stats.artifact_hits += 1;
            AnyEngine::F32(Engine::with_pool(plan, pool))
        }
        Some(AnyPlan::F64(plan)) => {
            stats.artifact_hits += 1;
            AnyEngine::F64(Engine::with_pool(plan, pool))
        }
        None => {
            stats.fallback_compiles += 1;
            let plan = Arc::new(planner.compile_seeded(g, key.seed));
            let engine = AnyEngine::build(plan, key.precision, pool);
            if overwrite {
                let published = match &engine {
                    AnyEngine::F32(e) => store.publish(key, e.plan()),
                    AnyEngine::F64(e) => store.publish(key, e.plan()),
                };
                match published {
                    Ok(_) => stats.published += 1,
                    Err(e) => {
                        eprintln!("plan-store: publishing {} failed ({e})", key.file_name());
                    }
                }
            } else {
                eprintln!(
                    "plan-store: {} belongs to another weight seed; serving the recompiled \
                     plan without overwriting it",
                    key.file_name()
                );
            }
            engine
        }
    }
}

impl NativeRuntime {
    /// Bring up every advertised route's plan and spawn the shared worker
    /// pool. Without a [`NativeConfig::plan_store`] each plan is compiled
    /// in-process — once, in f64, then lowered to the route's resolved
    /// tier. With a store, plans load from artifacts (cold start becomes a
    /// file read; no planner invocation on a warm store) and any miss or
    /// invalid artifact falls back to compilation, publishing the result.
    /// This is the once-per-startup step (the coordinator runs it on the
    /// engine thread before reporting ready, like PJRT artifact
    /// compilation). The engine set is derived from the manifest itself,
    /// so routes and engines can never desynchronize.
    pub fn build(cfg: &NativeConfig) -> NativeRuntime {
        let manifest = native_manifest(cfg);
        let pool = WorkerPool::shared(resolve_workers(cfg.workers));
        // fault plane reaches the data plane in exactly two places: worker
        // chunk dispatch (here) and artifact loads (engine_via_store below)
        pool.set_fault_plane(cfg.faults.clone());
        let zoo_models = zoo::all(cfg.scale);
        // explicit config > WINGAN_PRECISION env > per-model dse Auto
        let precision_policy = resolve_precision(cfg.precision);
        // explicit config > WINGAN_KERNEL env > host capability Auto
        let kernel_policy = resolve_kernel(cfg.kernel);
        let store = cfg.plan_store.as_ref().map(|root| PlanStore::open(root.clone()));
        let mut plan_stats = PlanCacheStats::default();
        let mut engines: BTreeMap<(String, String), AnyEngine> = BTreeMap::new();
        for e in &manifest.entries {
            let key = (e.model.clone(), e.method.clone());
            // one engine serves every batch bucket of a route
            if let btree_map::Entry::Vacant(slot) = engines.entry(key) {
                let g = zoo_models
                    .iter()
                    .find(|g| model_id(g.name) == e.model)
                    .expect("manifest route without a zoo model");
                let select = ROUTE_METHODS
                    .iter()
                    .find(|(m, _)| *m == e.method)
                    .expect("manifest route with unknown method")
                    .1;
                let planner = Planner::new(PlanOptions {
                    select,
                    precision: precision_policy,
                    kernel: kernel_policy,
                    ..Default::default()
                });
                // the tdc route is the bit-exact f64 reference anchor; fast
                // routes serve at the planner-resolved tier
                let precision = if e.method == "tdc" {
                    Precision::F64
                } else {
                    planner.resolve_precision(g)
                };
                let engine = match &store {
                    Some(store) => {
                        let plan_key =
                            PlanKey::new(g.name, cfg.scale, precision, &e.method, cfg.seed);
                        engine_via_store(
                            store,
                            &mut plan_stats,
                            g,
                            &planner,
                            &plan_key,
                            pool.clone(),
                            cfg.faults.as_deref(),
                        )
                    }
                    // one Arc'd compiled f64 plan per route: every engine
                    // clone (and any future co-resident engine) shares it;
                    // the f32 tier lowers it exactly once, at build time
                    None => {
                        let plan = Arc::new(planner.compile_seeded(g, cfg.seed));
                        AnyEngine::build(plan, precision, pool.clone())
                    }
                };
                slot.insert(engine);
            }
        }
        let entries = manifest.entries.iter().map(|e| (e.name.clone(), e.clone())).collect();
        NativeRuntime {
            engines,
            entries,
            pool,
            events: Arc::new(Mutex::new(Events::default())),
            plan_stats,
        }
    }

    /// Plan-cache counters from this runtime's startup: artifact hits,
    /// fallback compiles, load failures, publishes. All zeros when no
    /// [`NativeConfig::plan_store`] was configured.
    pub fn plan_stats(&self) -> PlanCacheStats {
        self.plan_stats
    }

    /// The worker pool shared by every route's engine.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Handle to the cumulative event counters (cloneable across threads).
    pub fn events_handle(&self) -> Arc<Mutex<Events>> {
        self.events.clone()
    }

    /// Snapshot of the cumulative events.
    pub fn events(&self) -> Events {
        crate::util::lock_unpoisoned(&self.events).clone()
    }

    /// The route engine for `(model, method)`, at whatever precision tier
    /// the route resolved to.
    pub fn engine(&self, model: &str, method: &str) -> Option<&AnyEngine> {
        self.engines.get(&(model.to_string(), method.to_string()))
    }

    /// Execute one packed batch buffer against a named route artifact.
    /// Mirrors the PJRT executable contract: fixed batch shape, padded
    /// slots are computed like real samples. The batch goes through
    /// [`crate::engine::Engine::run_batch`], so wide buckets parallelise
    /// across samples and narrow ones across stripes — bitwise identical
    /// either way. On an f32 route the buffer stays in single precision
    /// end to end.
    pub fn execute(&self, name: &str, input: &[f32]) -> Result<Vec<f32>, String> {
        let entry = self.entries.get(name).ok_or_else(|| format!("unknown artifact {name}"))?;
        if input.len() != entry.input_len() {
            return Err(format!(
                "artifact {name}: input length {} != expected {}",
                input.len(),
                entry.input_len()
            ));
        }
        let engine = self
            .engines
            .get(&(entry.model.clone(), entry.method.clone()))
            .ok_or_else(|| format!("no engine for route {}/{}", entry.model, entry.method))?;
        let (out, batch_events) = engine.run_packed(entry.batch, input);
        crate::util::lock_unpoisoned(&self.events).merge(&batch_events);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::PRECISION_ENV;

    fn tiny_cfg() -> NativeConfig {
        NativeConfig {
            scale: Scale::Tiny,
            buckets: vec![1, 2],
            workers: 2,
            models: Some(vec!["dcgan".into()]),
            ..Default::default()
        }
    }

    #[test]
    fn manifest_advertises_both_methods_and_buckets() {
        let m = native_manifest(&NativeConfig::default());
        // ids match python/compile/aot.py's manifest ("GP-GAN" -> "gpgan")
        assert_eq!(m.models(), vec!["artgan", "dcgan", "discogan", "gpgan"]);
        let buckets: Vec<usize> =
            m.buckets("dcgan", "winograd").iter().map(|e| e.batch).collect();
        assert_eq!(buckets, vec![1, 2, 4, 8]);
        assert!(m.find("gpgan_tdc_b4").is_some());
    }

    #[test]
    fn execute_batches_and_counts_events() {
        let rt = NativeRuntime::build(&tiny_cfg());
        let e1 = rt.entries.get("dcgan_winograd_b1").unwrap().clone();
        let out = rt.execute(&e1.name, &vec![0.5; e1.input_len()]).unwrap();
        assert_eq!(out.len(), e1.output_len());
        let after_one = rt.events().mults;
        assert!(after_one > 0);
        let e2 = rt.entries.get("dcgan_winograd_b2").unwrap().clone();
        rt.execute(&e2.name, &vec![0.5; e2.input_len()]).unwrap();
        // batch-2 adds exactly twice the single-sample work
        assert_eq!(rt.events().mults, after_one * 3);
    }

    #[test]
    fn all_routes_share_one_worker_pool() {
        let rt = NativeRuntime::build(&NativeConfig {
            scale: Scale::Tiny,
            buckets: vec![1, 2],
            workers: 2,
            ..Default::default()
        });
        let wino = rt.engine("dcgan", "winograd").unwrap();
        let tdc = rt.engine("gpgan", "tdc").unwrap();
        assert!(Arc::ptr_eq(wino.pool(), rt.pool()));
        assert!(Arc::ptr_eq(tdc.pool(), rt.pool()));
        assert_eq!(rt.pool().threads(), 2);
    }

    #[test]
    fn rejects_bad_inputs() {
        let rt = NativeRuntime::build(&tiny_cfg());
        assert!(rt.execute("nope", &[0.0; 4]).is_err());
        assert!(rt.execute("dcgan_winograd_b1", &[0.0; 4]).is_err());
    }

    #[test]
    fn winograd_and_tdc_routes_agree() {
        let rt = NativeRuntime::build(&tiny_cfg());
        let e = rt.entries.get("dcgan_winograd_b1").unwrap().clone();
        let x: Vec<f32> = (0..e.input_len()).map(|i| ((i % 13) as f32 - 6.0) / 6.0).collect();
        let a = rt.execute("dcgan_winograd_b1", &x).unwrap();
        let b = rt.execute("dcgan_tdc_b1", &x).unwrap();
        let diff = crate::util::bin::max_abs_diff(&a, &b);
        // the fast route may serve the f32 tier (Auto policy), so the A/B
        // tolerance is single-precision-accumulation sized, not 1e-4
        assert!(diff < 1e-3, "methods diverge: {diff}");
    }

    #[test]
    fn tdc_route_is_always_the_f64_reference_tier() {
        // even when the fast routes are forced to f32, the tdc anchor
        // stays full-precision
        let rt = NativeRuntime::build(&NativeConfig {
            precision: Some(Precision::F32),
            ..tiny_cfg()
        });
        assert_eq!(rt.engine("dcgan", "tdc").unwrap().precision(), Precision::F64);
        assert_eq!(rt.engine("dcgan", "winograd").unwrap().precision(), Precision::F32);
    }

    #[test]
    fn forced_precision_applies_to_fast_routes() {
        for p in [Precision::F32, Precision::F64] {
            let rt = NativeRuntime::build(&NativeConfig { precision: Some(p), ..tiny_cfg() });
            assert_eq!(rt.engine("dcgan", "winograd").unwrap().precision(), p);
            // and both tiers execute correctly end to end
            let e1 = rt.entries.get("dcgan_winograd_b1").unwrap().clone();
            let out = rt.execute(&e1.name, &vec![0.25; e1.input_len()]).unwrap();
            assert_eq!(out.len(), e1.output_len());
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn f32_route_tracks_the_f64_route() {
        let rt32 = NativeRuntime::build(&NativeConfig {
            precision: Some(Precision::F32),
            ..tiny_cfg()
        });
        let rt64 = NativeRuntime::build(&NativeConfig {
            precision: Some(Precision::F64),
            ..tiny_cfg()
        });
        let e = rt32.entries.get("dcgan_winograd_b1").unwrap().clone();
        let x: Vec<f32> = (0..e.input_len()).map(|i| ((i % 11) as f32 - 5.0) / 5.0).collect();
        let a = rt32.execute(&e.name, &x).unwrap();
        let b = rt64.execute(&e.name, &x).unwrap();
        let diff = crate::util::bin::max_abs_diff(&a, &b);
        assert!(diff < 1e-3, "f32 tier diverges from f64 tier: {diff}");
        // identical event accounting across tiers
        assert_eq!(rt32.events(), rt64.events());
    }

    fn temp_store_dir(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("wingan_serve_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn cold_build_publishes_and_warm_build_loads_without_planning() {
        let dir = temp_store_dir("warm");
        let cfg = NativeConfig { plan_store: Some(dir.clone()), ..tiny_cfg() };
        // cold start: empty store — both routes (winograd + tdc) compile
        // in-process and publish their artifacts
        let cold = NativeRuntime::build(&cfg);
        let s = cold.plan_stats();
        assert_eq!(s.artifact_hits, 0);
        assert_eq!(s.fallback_compiles, 2);
        assert_eq!(s.published, 2);
        assert_eq!(s.load_failures, 0);
        // warm start: every route comes straight off disk, the planner is
        // never invoked
        let warm = NativeRuntime::build(&cfg);
        let s = warm.plan_stats();
        assert_eq!(s.artifact_hits, 2);
        assert_eq!(s.fallback_compiles, 0);
        assert_eq!(s.load_failures, 0);
        // and the loaded plans execute bit-identically to the compiled ones
        let e = cold.entries.get("dcgan_winograd_b2").unwrap().clone();
        let x: Vec<f32> = (0..e.input_len()).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
        assert_eq!(cold.execute(&e.name, &x).unwrap(), warm.execute(&e.name, &x).unwrap());
        let t = cold.entries.get("dcgan_tdc_b1").unwrap().clone();
        let xt = &x[..t.input_len()];
        assert_eq!(cold.execute(&t.name, xt).unwrap(), warm.execute(&t.name, xt).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn no_store_configured_reports_zero_plan_stats() {
        let rt = NativeRuntime::build(&tiny_cfg());
        assert_eq!(rt.plan_stats(), crate::artifact::PlanCacheStats::default());
    }

    #[test]
    fn corrupt_artifacts_fall_back_cleanly_and_are_counted() {
        let dir = temp_store_dir("corrupt");
        let cfg = NativeConfig { plan_store: Some(dir.clone()), ..tiny_cfg() };
        let cold = NativeRuntime::build(&cfg);
        assert_eq!(cold.plan_stats().published, 2);
        // truncate every published artifact to garbage
        for entry in std::fs::read_dir(dir.join("tiny")).unwrap() {
            let path = entry.unwrap().path();
            std::fs::write(&path, b"WGANPLAN truncated mid-header").unwrap();
        }
        let rebuilt = NativeRuntime::build(&cfg);
        let s = rebuilt.plan_stats();
        assert_eq!(s.load_failures, 2, "both corrupt artifacts must be counted");
        assert_eq!(s.fallback_compiles, 2, "and both routes must recompile");
        assert_eq!(s.quarantined, 2, "both corrupt artifacts must be moved aside");
        let parked = std::fs::read_dir(dir.join("tiny"))
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().path().extension().is_some_and(|x| x == "quarantined")
            })
            .count();
        assert_eq!(parked, 2, "quarantined bytes stay on disk for forensics");
        // the fallback republished valid artifacts and still serves
        // correct, bit-identical outputs
        let e = cold.entries.get("dcgan_winograd_b1").unwrap().clone();
        let x: Vec<f32> = (0..e.input_len()).map(|i| ((i % 5) as f32 - 2.0) / 2.0).collect();
        assert_eq!(cold.execute(&e.name, &x).unwrap(), rebuilt.execute(&e.name, &x).unwrap());
        let healed = NativeRuntime::build(&cfg);
        assert_eq!(healed.plan_stats().artifact_hits, 2, "publish-on-fallback heals the store");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_artifact_load_faults_quarantine_and_recompile() {
        let dir = temp_store_dir("inject");
        let cfg = NativeConfig { plan_store: Some(dir.clone()), ..tiny_cfg() };
        // warm the store with two valid artifacts
        assert_eq!(NativeRuntime::build(&cfg).plan_stats().published, 2);
        // one injected load error: the first route's (perfectly valid)
        // artifact is treated exactly like corrupt bytes — counted,
        // quarantined, recompiled around — and the second loads normally
        let plane = crate::faultinject::FaultPlane::parse("seed=3;artifact_load:error*1@1")
            .expect("valid fault spec");
        let plane = Arc::new(plane);
        let faulted =
            NativeRuntime::build(&NativeConfig { faults: Some(plane.clone()), ..cfg.clone() });
        assert_eq!(plane.fired_at(crate::faultinject::FaultSite::ArtifactLoad), 1);
        let s = faulted.plan_stats();
        assert_eq!(s.load_failures, 1);
        assert_eq!(s.quarantined, 1);
        assert_eq!(s.artifact_hits, 1);
        assert_eq!(s.fallback_compiles, 1);
        // publish-on-fallback healed the quarantined slot: the next boot
        // (no faults) is fully warm again
        let healed = NativeRuntime::build(&cfg);
        assert_eq!(healed.plan_stats().artifact_hits, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn seed_mismatched_artifacts_are_served_around_but_never_overwritten() {
        let dir = temp_store_dir("seedkeep");
        let cfg = NativeConfig {
            precision: Some(Precision::F64),
            plan_store: Some(dir.clone()),
            ..tiny_cfg()
        }; // weight seed 42 (the default)
        NativeRuntime::build(&cfg);
        let wino_path = dir.join("tiny/dcgan.winograd.f64.plan");
        let before = std::fs::read(&wino_path).unwrap();
        // a server misconfigured to another weight seed: every route falls
        // back to compilation, but the seed-42 store must survive intact
        let other = NativeRuntime::build(&NativeConfig { seed: 7, ..cfg.clone() });
        let s = other.plan_stats();
        assert_eq!(s.artifact_hits, 0);
        assert_eq!(s.load_failures, 2);
        assert_eq!(s.fallback_compiles, 2);
        assert_eq!(s.published, 0, "a seed mismatch must not overwrite the store");
        assert_eq!(s.quarantined, 0, "a seed mismatch must not quarantine a valid artifact");
        assert_eq!(std::fs::read(&wino_path).unwrap(), before, "artifact bytes untouched");
        // and the original configuration still boots warm
        let warm = NativeRuntime::build(&cfg);
        assert_eq!(warm.plan_stats().artifact_hits, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shape_stale_artifacts_are_recompiled_not_served() {
        let dir = temp_store_dir("stale");
        // park a *Small*-scale plan under the Tiny winograd key: every
        // decode/key check passes, but the geometry belongs to another zoo
        // scale — serving it would panic at request time
        let store = PlanStore::open(dir.clone());
        let small = Planner::default().compile_seeded(&zoo::dcgan(Scale::Small), 42);
        let key = PlanKey::new("dcgan", Scale::Tiny, Precision::F64, "winograd", 42);
        store.publish(&key, &small).unwrap();
        let cfg = NativeConfig {
            precision: Some(Precision::F64),
            plan_store: Some(dir.clone()),
            ..tiny_cfg()
        };
        let rt = NativeRuntime::build(&cfg);
        let s = rt.plan_stats();
        assert_eq!(s.artifact_hits, 0, "a shape-stale artifact must never be served");
        assert_eq!(s.load_failures, 1, "the stale winograd artifact is counted");
        assert_eq!(s.fallback_compiles, 2, "both routes recompile (tdc was simply missing)");
        assert_eq!(s.quarantined, 1, "the stale artifact is moved aside, not re-parsed forever");
        // the fallback serves the *current* zoo's shapes
        let e = rt.entries.get("dcgan_winograd_b1").unwrap().clone();
        let out = rt.execute(&e.name, &vec![0.5; e.input_len()]).unwrap();
        assert_eq!(out.len(), e.output_len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forced_precision_store_round_trips_the_f32_tier() {
        let dir = temp_store_dir("f32tier");
        let cfg = NativeConfig {
            precision: Some(Precision::F32),
            plan_store: Some(dir.clone()),
            ..tiny_cfg()
        };
        let cold = NativeRuntime::build(&cfg);
        assert_eq!(cold.engine("dcgan", "winograd").unwrap().precision(), Precision::F32);
        // the fast route's artifact is the lowered f32 plan; the tdc
        // anchor's artifact is f64
        assert!(dir.join("tiny/dcgan.winograd.f32.plan").exists());
        assert!(dir.join("tiny/dcgan.tdc.f64.plan").exists());
        let warm = NativeRuntime::build(&cfg);
        assert_eq!(warm.plan_stats().artifact_hits, 2);
        assert_eq!(warm.engine("dcgan", "winograd").unwrap().precision(), Precision::F32);
        // loaded f32 plan == lowered-then-roundtripped plan, bit for bit
        let e = cold.entries.get("dcgan_winograd_b1").unwrap().clone();
        let x: Vec<f32> = (0..e.input_len()).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        assert_eq!(cold.execute(&e.name, &x).unwrap(), warm.execute(&e.name, &x).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn env_name_is_stable() {
        // the documented override variables (exercised end-to-end by ops,
        // not mutated here: tests share one process environment)
        assert_eq!(PRECISION_ENV, "WINGAN_PRECISION");
        assert_eq!(crate::engine::plan::KERNEL_ENV, "WINGAN_KERNEL");
    }

    #[test]
    fn kernel_choice_does_not_change_served_outputs() {
        use crate::winograd::kernel::KernelKind;
        // same route, both micro-kernels forced, f64 tier: the served
        // bytes must be bitwise identical (the SIMD kernel's contract)
        let scalar_rt = NativeRuntime::build(&NativeConfig {
            precision: Some(Precision::F64),
            kernel: Some(KernelKind::Scalar),
            ..tiny_cfg()
        });
        let simd_rt = NativeRuntime::build(&NativeConfig {
            precision: Some(Precision::F64),
            kernel: Some(KernelKind::Simd),
            ..tiny_cfg()
        });
        let e = scalar_rt.entries.get("dcgan_winograd_b2").unwrap().clone();
        let x: Vec<f32> =
            (0..2 * e.input_len()).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
        let a = scalar_rt.execute(&e.name, &x).unwrap();
        let b = simd_rt.execute(&e.name, &x).unwrap();
        assert!(a == b, "kernel dispatch must not change served outputs");
        assert_eq!(scalar_rt.events(), simd_rt.events(), "same event accounting");
    }
}
