//! Ahead-of-time plan compilation (the "compile" half of the
//! plan-compile / execute split).
//!
//! A [`Planner`] turns a [`Gan`] description plus concrete weights into a
//! [`ModelPlan`]: per layer, everything the seed's per-call functional
//! simulator used to re-derive on every request is now computed exactly
//! once —
//! * the TDC phase decomposition (S² phase filters + input offsets),
//! * the Winograd-domain transformed filters `G g Gᵀ`, sparsity-classified
//!   and reordered into the zero-row-free `n² x N` layout,
//! * the per-layer method (TDC vs Winograd fast algorithm), chosen at
//!   compile time by racing the two through the `dse` cycle model — the
//!   Zhang-et-al. point that method selection belongs in the compiler, not
//!   on the request path,
//! * the line-buffer geometry (depth, width, word budget) the execution
//!   engine's event accounting is pinned to.
//!
//! # Precision tiers
//!
//! Compilation always runs in `f64`: phase decomposition is exact tap
//! selection, and the Winograd filter transforms are computed at full
//! precision. A compiled plan is then **lowered** to the serving precision
//! with [`ModelPlan::lower`] — for the f32 fast path, the reordered filter
//! slabs, phase filter banks and raw weights are quantized *after* the
//! exact `G g Gᵀ` transform, never before. Which tier a model serves at is
//! decided per plan by [`Planner::resolve_precision`]: an explicit
//! [`PrecisionSelect::Force`] wins, otherwise the `dse` bandwidth analysis
//! recommends a tier ([`crate::dse::recommend_precision`]). End-to-end
//! overrides ([`crate::engine::NativeConfig::precision`],
//! `wingan serve --precision`, the [`PRECISION_ENV`] environment variable)
//! all funnel through [`resolve_precision`].
//!
//! # Kernel dispatch
//!
//! The GEMM micro-kernel the Winograd datapath runs on is resolved the
//! same way: an explicit [`KernelSelect::Force`] wins (CLI `--kernel`,
//! [`crate::engine::NativeConfig::kernel`], the [`KERNEL_ENV`] variable),
//! otherwise [`crate::dse::recommend_kernel`] picks SIMD whenever the host
//! supports it. The decision is feature-checked **once** here and recorded
//! on [`TileGeometry::kernel`], so dispatch is part of the compiled plan
//! (visible in `wingan plan inspect`) rather than re-probed per request;
//! forcing SIMD on a host without it falls back to the scalar kernel with
//! a logged correction.

use crate::accel::config::AccelConfig;
use crate::accel::cycle::simulate_layer;
use crate::gan::workload::Method;
use crate::gan::zoo::{Gan, Kind, Layer};
use crate::tdc::{self, PhaseFilter};
use crate::util::elem::{Elem, Precision};
use crate::util::prng::Rng;
use crate::util::tensor::Filter4;
use crate::winograd::kernel::{simd_available, KernelKind};
use crate::winograd::layout::{reorder_filter, ReorderedFilter};
use crate::winograd::transforms::{M as M_TILE, N as N_TILE};

/// Compile-time method selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Select {
    /// Race TDC vs Winograd through the cycle model per layer (Winograd is
    /// only eligible when `K_C <= 3`, the F(2x2,3x3) support bound).
    Auto,
    /// Force one method on every deconv layer. `Force(Method::Tdc)` yields
    /// the *exact* datapath: arithmetic bit-identical (f64) to the
    /// layer-composed standard-DeConv reference.
    Force(Method),
}

/// Compile-time precision selection policy (the precision analogue of
/// [`Select`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecisionSelect {
    /// Per-plan recommendation from the `dse` bandwidth analysis
    /// ([`crate::dse::recommend_precision`]): f32 when the modelled
    /// datapath is transfer-bound at the f64 word size, f64 otherwise.
    Auto,
    /// Force one tier for every plan this planner lowers.
    Force(Precision),
}

/// Compile-time GEMM micro-kernel selection policy (the kernel analogue
/// of [`Select`] / [`PrecisionSelect`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelSelect {
    /// Per-plan recommendation ([`crate::dse::recommend_kernel`]): the
    /// SIMD kernel whenever the host supports it, scalar otherwise.
    Auto,
    /// Force one kernel for every plan this planner compiles. Forcing
    /// [`KernelKind::Simd`] on a host without AVX2/NEON resolves to the
    /// scalar kernel with a logged correction.
    Force(KernelKind),
}

/// Environment variable consulted by [`resolve_precision`] when no
/// explicit precision is requested (the precision analogue of
/// `WINGAN_WORKERS`).
pub const PRECISION_ENV: &str = "WINGAN_PRECISION";

/// Environment variable consulted by [`resolve_kernel`] when no explicit
/// kernel is requested (mirrors [`PRECISION_ENV`]; the CI matrix sets
/// `WINGAN_KERNEL=scalar|simd` to pin both dispatch arms).
pub const KERNEL_ENV: &str = "WINGAN_KERNEL";

/// The single source of truth for micro-kernel resolution:
///
/// 1. `requested`, when set (an explicit CLI `--kernel` flag or
///    [`crate::engine::NativeConfig::kernel`] field);
/// 2. the [`KERNEL_ENV`] environment variable, when it parses as a kernel
///    name;
/// 3. [`KernelSelect::Auto`] — each plan asks the host capability probe.
pub fn resolve_kernel(requested: Option<KernelKind>) -> KernelSelect {
    resolve_kernel_with(requested, std::env::var(KERNEL_ENV).ok())
}

/// [`resolve_kernel`] with the environment injected, so the precedence
/// rules are testable without mutating process-global state.
fn resolve_kernel_with(requested: Option<KernelKind>, env: Option<String>) -> KernelSelect {
    if let Some(k) = requested {
        return KernelSelect::Force(k);
    }
    if let Some(v) = env {
        if let Ok(k) = KernelKind::parse(&v) {
            return KernelSelect::Force(k);
        }
    }
    KernelSelect::Auto
}

/// The single source of truth for serving-precision resolution:
///
/// 1. `requested`, when set (an explicit CLI `--precision` flag or
///    [`crate::engine::NativeConfig::precision`] field);
/// 2. the [`PRECISION_ENV`] environment variable, when it parses as a
///    precision name;
/// 3. [`PrecisionSelect::Auto`] — each plan asks the `dse` model.
pub fn resolve_precision(requested: Option<Precision>) -> PrecisionSelect {
    resolve_precision_with(requested, std::env::var(PRECISION_ENV).ok())
}

/// [`resolve_precision`] with the environment injected, so the precedence
/// rules are testable without mutating process-global state.
fn resolve_precision_with(requested: Option<Precision>, env: Option<String>) -> PrecisionSelect {
    if let Some(p) = requested {
        return PrecisionSelect::Force(p);
    }
    if let Some(v) = env {
        if let Ok(p) = Precision::parse(&v) {
            return PrecisionSelect::Force(p);
        }
    }
    PrecisionSelect::Auto
}

/// Plan-compile options.
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    /// method-selection policy (auto DSE race, or forced)
    pub select: Select,
    /// precision-selection policy (auto DSE recommendation, or forced)
    pub precision: PrecisionSelect,
    /// GEMM micro-kernel selection policy (auto host probe, or forced)
    pub kernel: KernelSelect,
    /// accelerator config the method race + precision recommendation +
    /// line-buffer geometry use
    pub cfg: AccelConfig,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            select: Select::Auto,
            precision: PrecisionSelect::Auto,
            kernel: KernelSelect::Auto,
            cfg: AccelConfig::default(),
        }
    }
}

/// Precompiled stripe/tile geometry for a deconv layer's Winograd
/// datapath, derived once at plan-compile time from the layer's input
/// extent (m = 2 outputs per tile dim, so per phase the `H x W` map is
/// covered by `tiles_h x tiles_w` tiles over a tile-aligned
/// `ho_t x wo_t` extent).
///
/// The execution engine batches all `tiles_w` tiles of a stripe (one tile
/// row) into a single Winograd-domain GEMM per live position — this struct
/// is the blocking geometry that batching reads, instead of re-deriving it
/// per layer call. Zeroed for layers that never run the Winograd datapath.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileGeometry {
    /// tile-aligned per-phase output rows: `H` rounded up to a multiple of m
    pub ho_t: usize,
    /// tile-aligned per-phase output cols: `W` rounded up to a multiple of m
    pub wo_t: usize,
    /// stripes per phase (tile rows): `ho_t / m`
    pub tiles_h: usize,
    /// tiles per stripe — the GEMM batch width `T`: `wo_t / m`
    pub tiles_w: usize,
    /// GEMM micro-kernel the stripe GEMMs dispatch to, resolved once at
    /// plan-compile / artifact-load time (default: scalar; layers that
    /// never run the Winograd datapath keep the default)
    pub kernel: KernelKind,
}

/// One layer's precompiled execution plan, at element precision `E`
/// (defaults to the f64 reference tier; f32 plans come from
/// [`LayerPlan::cast_to`] via [`ModelPlan::lower`]).
#[derive(Clone, Debug)]
pub struct LayerPlan<E: Elem = f64> {
    /// the zoo layer this plan executes (including its hand-off activation)
    pub layer: Layer,
    /// compile-time method decision (Conv layers always run the spatial
    /// conv datapath and record `Method::Tdc`)
    pub method: Method,
    /// raw weights: conv-transpose layout `[C_in, C_out, K, K]` for deconv,
    /// correlation layout for conv
    pub weights: Filter4<E>,
    /// TDC phase decomposition, done once (deconv only; empty for conv)
    pub phases: Vec<PhaseFilter<E>>,
    /// Winograd-domain filters, transformed + sparsity-reordered once
    /// (only populated when `method == Winograd`)
    pub reordered: Vec<ReorderedFilter<E>>,
    /// TDC-converted kernel width
    pub kc: usize,
    /// Winograd stripe/tile blocking geometry (zeroed for conv layers and
    /// TDC-method plans, which don't tile)
    pub tiles: TileGeometry,
    /// functional line-buffer depth in rows (n+m Winograd, K_C+1 TDC)
    pub linebuf_depth: usize,
    /// line-buffer capacity in f32 words at this layer's geometry
    pub linebuf_words: usize,
}

impl<E: Elem> LayerPlan<E> {
    /// Winograd-domain multiplications per (tile, c_in, c_out) — the live
    /// position count summed over phases (C(K_C) of eq. 5).
    pub fn live_positions(&self) -> usize {
        self.reordered.iter().map(|r| r.live.len()).sum()
    }

    /// The same compiled layer at another precision: weights, phase filter
    /// banks and reordered Winograd slabs converted elementwise, every
    /// precision-free field (geometry, method, sparsity structure) copied.
    pub fn cast_to<T: Elem>(&self) -> LayerPlan<T> {
        LayerPlan {
            layer: self.layer,
            method: self.method,
            weights: self.weights.cast_to(),
            phases: self.phases.iter().map(|p| p.cast_to()).collect(),
            reordered: self.reordered.iter().map(|r| r.cast_to()).collect(),
            kc: self.kc,
            tiles: self.tiles,
            linebuf_depth: self.linebuf_depth,
            linebuf_words: self.linebuf_words,
        }
    }
}

/// A whole generator, compiled: everything [`crate::engine::Engine`] needs
/// to execute requests with zero per-request derivation. Generic over the
/// element precision (`f64` reference tier by default).
#[derive(Clone, Debug)]
pub struct ModelPlan<E: Elem = f64> {
    /// zoo model name (e.g. `"DCGAN"`)
    pub model: String,
    /// per-layer plans, in execution order
    pub layers: Vec<LayerPlan<E>>,
    /// `[C, H, W]` of the model input (first layer's input geometry)
    pub input_shape: (usize, usize, usize),
    /// `[C, H, W]` of the model output
    pub output_shape: (usize, usize, usize),
}

impl<E: Elem> ModelPlan<E> {
    /// Flat element count of one input sample.
    pub fn input_len(&self) -> usize {
        self.input_shape.0 * self.input_shape.1 * self.input_shape.2
    }

    /// Flat element count of one output sample.
    pub fn output_len(&self) -> usize {
        self.output_shape.0 * self.output_shape.1 * self.output_shape.2
    }

    /// Layers that will run the Winograd fast path.
    pub fn n_winograd_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.method == Method::Winograd).count()
    }

    /// The precision tier this plan executes at.
    pub fn precision(&self) -> Precision {
        E::PRECISION
    }

    /// Lower the whole plan to another precision tier. Method decisions,
    /// tile geometry and sparsity structure are precision-free and carry
    /// over unchanged; only the numeric banks are converted (for
    /// `f64 → f32`, quantized after the exact f64 transforms).
    pub fn lower<T: Elem>(&self) -> ModelPlan<T> {
        ModelPlan {
            model: self.model.clone(),
            layers: self.layers.iter().map(|l| l.cast_to()).collect(),
            input_shape: self.input_shape,
            output_shape: self.output_shape,
        }
    }
}

/// The plan compiler.
#[derive(Clone, Debug, Default)]
pub struct Planner {
    pub opts: PlanOptions,
}

impl Planner {
    /// Planner with explicit options (`Planner::default()` races methods
    /// through the DSE cycle model at the default accelerator config).
    pub fn new(opts: PlanOptions) -> Planner {
        Planner { opts }
    }

    /// Pick the method for one deconv layer.
    fn select_method(&self, l: &Layer) -> Method {
        let winograd_able = tdc::kc(l.k, l.s) <= crate::winograd::R;
        match self.opts.select {
            Select::Force(m) => match m {
                Method::Winograd if winograd_able => Method::Winograd,
                // the engine has no zero-padded datapath (the cycle model
                // covers that baseline); record the method that actually
                // executes so Events are never mislabeled
                _ => Method::Tdc,
            },
            Select::Auto => {
                if !winograd_able {
                    return Method::Tdc;
                }
                // compile-time DSE race: modelled wall-clock decides
                let t_win = simulate_layer(l, Method::Winograd, &self.opts.cfg).t_total;
                let t_tdc = simulate_layer(l, Method::Tdc, &self.opts.cfg).t_total;
                if t_win <= t_tdc {
                    Method::Winograd
                } else {
                    Method::Tdc
                }
            }
        }
    }

    /// The precision tier this planner lowers `g`'s plan at: an explicit
    /// [`PrecisionSelect::Force`] wins, otherwise the `dse` bandwidth
    /// analysis recommends one per model
    /// ([`crate::dse::recommend_precision`]).
    pub fn resolve_precision(&self, g: &Gan) -> Precision {
        match self.opts.precision {
            PrecisionSelect::Force(p) => p,
            PrecisionSelect::Auto => crate::dse::recommend_precision(g, &self.opts.cfg),
        }
    }

    /// The GEMM micro-kernel this planner stamps on Winograd-method layers
    /// ([`TileGeometry::kernel`]): an explicit [`KernelSelect::Force`]
    /// wins, subject to the host capability check (forcing SIMD on a host
    /// without AVX2/NEON logs a correction and compiles the scalar
    /// kernel); [`KernelSelect::Auto`] asks
    /// [`crate::dse::recommend_kernel`].
    pub fn resolve_kernel(&self) -> KernelKind {
        match self.opts.kernel {
            KernelSelect::Force(KernelKind::Simd) if !simd_available() => {
                eprintln!(
                    "wingan: kernel=simd requested but the host has no \
                     AVX2/NEON; compiling the scalar kernel"
                );
                KernelKind::Scalar
            }
            KernelSelect::Force(k) => k,
            KernelSelect::Auto => crate::dse::recommend_kernel(),
        }
    }

    /// Compile one layer.
    pub fn compile_layer(&self, l: &Layer, weights: Filter4) -> LayerPlan {
        assert_eq!(weights.c_in, l.c_in, "weight/layer C_in mismatch");
        assert_eq!(weights.c_out, l.c_out, "weight/layer C_out mismatch");
        assert_eq!((weights.kh, weights.kw), (l.k, l.k), "weight/layer kernel mismatch");
        match l.kind {
            Kind::Conv => {
                let depth = l.k + 1;
                LayerPlan {
                    layer: *l,
                    method: Method::Tdc,
                    weights,
                    phases: Vec::new(),
                    reordered: Vec::new(),
                    kc: l.k,
                    tiles: TileGeometry::default(),
                    linebuf_depth: depth,
                    linebuf_words: depth * (l.w_in + 2 * l.p) * l.c_in,
                }
            }
            Kind::Deconv => {
                let method = self.select_method(l);
                let kc = tdc::kc(l.k, l.s);
                let phases = tdc::decompose(&weights, l.s, l.p);
                let reordered = if method == Method::Winograd {
                    phases.iter().map(reorder_filter).collect()
                } else {
                    Vec::new()
                };
                let tiles = if method == Method::Winograd {
                    let ho_t = l.h_in.div_ceil(M_TILE) * M_TILE;
                    let wo_t = l.w_in.div_ceil(M_TILE) * M_TILE;
                    TileGeometry {
                        ho_t,
                        wo_t,
                        tiles_h: ho_t / M_TILE,
                        tiles_w: wo_t / M_TILE,
                        kernel: self.resolve_kernel(),
                    }
                } else {
                    TileGeometry::default()
                };
                let (depth, width) = if method == Method::Winograd {
                    // n+m lines of the phase-padded map (paper §IV.B)
                    (N_TILE + M_TILE, tiles.wo_t + crate::winograd::R - 1)
                } else {
                    (kc + 1, l.w_in + kc - 1)
                };
                LayerPlan {
                    layer: *l,
                    method,
                    weights,
                    phases,
                    reordered,
                    kc,
                    tiles,
                    linebuf_depth: depth,
                    linebuf_words: depth * width * l.c_in,
                }
            }
        }
    }

    /// Compile a whole generator with explicit per-layer weights (always at
    /// the f64 reference tier; see [`ModelPlan::lower`] for the f32 tier).
    pub fn compile(&self, g: &Gan, weights: Vec<Filter4>) -> ModelPlan {
        assert_eq!(weights.len(), g.layers.len(), "one filter bank per layer");
        let layers: Vec<LayerPlan> = g
            .layers
            .iter()
            .zip(weights)
            .map(|(l, w)| self.compile_layer(l, w))
            .collect();
        let first = &g.layers[0];
        let last = g.layers.last().unwrap();
        ModelPlan {
            model: g.name.to_string(),
            input_shape: (first.c_in, first.h_in, first.w_in),
            output_shape: (last.c_out, last.h_out(), last.w_out()),
            layers,
        }
    }

    /// Compile with deterministic seeded weights (He-style scaling keeps the
    /// composed activations O(1) across the stack — the serving path hands
    /// f32 buffers around).
    pub fn compile_seeded(&self, g: &Gan, seed: u64) -> ModelPlan {
        self.compile(g, seeded_weights(g, seed))
    }
}

/// Deterministic per-(model, layer) weight banks.
pub fn seeded_weights(g: &Gan, seed: u64) -> Vec<Filter4> {
    g.layers
        .iter()
        .enumerate()
        .map(|(li, l)| {
            let mut s = seed ^ (li as u64).wrapping_mul(0x9E3779B97F4A7C15);
            for b in g.name.bytes() {
                s = s.wrapping_mul(0x100000001B3) ^ b as u64;
            }
            let mut rng = Rng::new(s);
            let n = l.c_in * l.c_out * l.k * l.k;
            let scale = 1.0 / ((l.c_in * l.k * l.k) as f64).sqrt();
            let data = rng.normal_vec(n).into_iter().map(|v| v * scale).collect();
            Filter4::from_vec(l.c_in, l.c_out, l.k, l.k, data)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gan::zoo::{self, Scale};

    #[test]
    fn auto_select_prefers_winograd_on_paper_layers() {
        // every Table-I deconv class has K_C <= 3 and a faster Winograd
        // cycle count, so Auto must pick Winograd on all deconv layers
        let planner = Planner::default();
        for g in zoo::all(Scale::Paper) {
            let plan = planner.compile_seeded(&g, 7);
            for lp in plan.layers.iter().filter(|l| l.layer.kind == Kind::Deconv) {
                assert_eq!(lp.method, Method::Winograd, "{} {:?}", g.name, lp.layer);
                assert_eq!(lp.reordered.len(), lp.layer.s * lp.layer.s);
            }
        }
    }

    #[test]
    fn forced_tdc_skips_winograd_precompute() {
        let planner = Planner::new(PlanOptions {
            select: Select::Force(Method::Tdc),
            ..Default::default()
        });
        let plan = planner.compile_seeded(&zoo::dcgan(Scale::Small), 7);
        for lp in &plan.layers {
            assert_eq!(lp.method, Method::Tdc);
            assert!(lp.reordered.is_empty());
            assert!(!lp.phases.is_empty());
        }
    }

    #[test]
    fn live_positions_match_paper_constants() {
        // DCGAN K=5 S=2: C(K_C) = 49; K=4 S=2 models: 36
        let planner = Planner::default();
        let plan = planner.compile_seeded(&zoo::dcgan(Scale::Small), 7);
        assert_eq!(plan.layers[0].live_positions(), 49);
        let plan4 = planner.compile_seeded(&zoo::gpgan(Scale::Small), 7);
        assert_eq!(plan4.layers[0].live_positions(), 36);
    }

    #[test]
    fn winograd_tile_geometry_precomputed() {
        let plan = Planner::default().compile_seeded(&zoo::dcgan(Scale::Small), 7);
        for lp in &plan.layers {
            if lp.method == Method::Winograd {
                assert_eq!(lp.tiles.ho_t, lp.layer.h_in.div_ceil(M_TILE) * M_TILE);
                assert_eq!(lp.tiles.wo_t, lp.layer.w_in.div_ceil(M_TILE) * M_TILE);
                assert_eq!(lp.tiles.tiles_h * M_TILE, lp.tiles.ho_t);
                assert_eq!(lp.tiles.tiles_w * M_TILE, lp.tiles.wo_t);
                assert!(lp.tiles.tiles_w > 0);
            } else {
                assert_eq!(lp.tiles, TileGeometry::default());
            }
        }
        let tdc_plan = Planner::new(PlanOptions {
            select: Select::Force(Method::Tdc),
            ..Default::default()
        })
        .compile_seeded(&zoo::dcgan(Scale::Small), 7);
        assert!(tdc_plan.layers.iter().all(|lp| lp.tiles == TileGeometry::default()));
    }

    #[test]
    fn shapes_chain_through_plan() {
        let planner = Planner::default();
        for g in zoo::all(Scale::Small) {
            let plan = planner.compile_seeded(&g, 3);
            assert_eq!(plan.output_shape, (3, 64, 64), "{}", g.name);
            assert_eq!(plan.layers.len(), g.layers.len());
        }
    }

    #[test]
    fn seeded_weights_deterministic_and_model_distinct() {
        let g = zoo::dcgan(Scale::Small);
        let a = seeded_weights(&g, 42);
        let b = seeded_weights(&g, 42);
        assert_eq!(a[0].data, b[0].data);
        let c = seeded_weights(&zoo::gpgan(Scale::Small), 42);
        assert_ne!(a[1].data.len(), 0);
        // different models draw from different streams even at equal seed
        assert_ne!(a[0].data[..4], c[0].data[..4]);
    }

    #[test]
    fn lower_quantizes_after_the_exact_transform() {
        let plan = Planner::default().compile_seeded(&zoo::dcgan(Scale::Tiny), 7);
        assert_eq!(plan.precision(), Precision::F64);
        let plan32: ModelPlan<f32> = plan.lower();
        assert_eq!(plan32.precision(), Precision::F32);
        assert_eq!(plan32.model, plan.model);
        assert_eq!(plan32.input_shape, plan.input_shape);
        assert_eq!(plan32.layers.len(), plan.layers.len());
        for (l32, l64) in plan32.layers.iter().zip(&plan.layers) {
            assert_eq!(l32.method, l64.method);
            assert_eq!(l32.tiles, l64.tiles);
            assert_eq!(l32.layer.act, l64.layer.act);
            assert_eq!(l32.reordered.len(), l64.reordered.len());
            for (r32, r64) in l32.reordered.iter().zip(&l64.reordered) {
                assert_eq!(r32.live, r64.live);
                // each slab entry is the f64 transform result rounded once
                for (a, b) in r32.u.iter().zip(&r64.u) {
                    assert_eq!(*a, *b as f32);
                }
            }
        }
    }

    #[test]
    fn precision_resolution_precedence() {
        // injected env keeps this test free of process-global mutation
        assert_eq!(
            resolve_precision_with(Some(Precision::F32), Some("f64".into())),
            PrecisionSelect::Force(Precision::F32),
            "explicit request wins"
        );
        assert_eq!(
            resolve_precision_with(None, Some("f32".into())),
            PrecisionSelect::Force(Precision::F32),
            "env fills in"
        );
        assert_eq!(
            resolve_precision_with(None, Some(" F64 ".into())),
            PrecisionSelect::Force(Precision::F64),
            "env is trimmed + case-insensitive"
        );
        assert_eq!(
            resolve_precision_with(None, Some("garbage".into())),
            PrecisionSelect::Auto,
            "unparseable env -> auto"
        );
        assert_eq!(resolve_precision_with(None, None), PrecisionSelect::Auto);
    }

    #[test]
    fn kernel_resolution_precedence() {
        // injected env keeps this test free of process-global mutation
        assert_eq!(
            resolve_kernel_with(Some(KernelKind::Scalar), Some("simd".into())),
            KernelSelect::Force(KernelKind::Scalar),
            "explicit request wins"
        );
        assert_eq!(
            resolve_kernel_with(None, Some("simd".into())),
            KernelSelect::Force(KernelKind::Simd),
            "env fills in"
        );
        assert_eq!(
            resolve_kernel_with(None, Some(" Scalar ".into())),
            KernelSelect::Force(KernelKind::Scalar),
            "env is trimmed + case-insensitive"
        );
        assert_eq!(
            resolve_kernel_with(None, Some("avx512".into())),
            KernelSelect::Auto,
            "unparseable env -> auto"
        );
        assert_eq!(resolve_kernel_with(None, None), KernelSelect::Auto);
    }

    #[test]
    fn kernel_choice_is_stamped_on_winograd_geometry() {
        let forced = Planner::new(PlanOptions {
            kernel: KernelSelect::Force(KernelKind::Scalar),
            ..Default::default()
        });
        let plan = forced.compile_seeded(&zoo::dcgan(Scale::Small), 7);
        for lp in &plan.layers {
            if lp.method == Method::Winograd {
                assert_eq!(lp.tiles.kernel, KernelKind::Scalar);
            } else {
                assert_eq!(lp.tiles, TileGeometry::default());
            }
        }
        // Auto and Force(Simd) both respect the host capability: the
        // stamped kernel is Simd iff the host supports it
        let auto = Planner::default();
        let want = if crate::winograd::kernel::simd_available() {
            KernelKind::Simd
        } else {
            KernelKind::Scalar
        };
        assert_eq!(auto.resolve_kernel(), want);
        let forced_simd = Planner::new(PlanOptions {
            kernel: KernelSelect::Force(KernelKind::Simd),
            ..Default::default()
        });
        assert_eq!(forced_simd.resolve_kernel(), want, "simd falls back when absent");
        // lowering preserves the stamped kernel
        let plan32: ModelPlan<f32> = plan.lower();
        for (l32, l64) in plan32.layers.iter().zip(&plan.layers) {
            assert_eq!(l32.tiles.kernel, l64.tiles.kernel);
        }
    }

    #[test]
    fn planner_resolves_precision_per_policy() {
        let g = zoo::dcgan(Scale::Paper);
        let forced = Planner::new(PlanOptions {
            precision: PrecisionSelect::Force(Precision::F64),
            ..Default::default()
        });
        assert_eq!(forced.resolve_precision(&g), Precision::F64);
        let forced32 = Planner::new(PlanOptions {
            precision: PrecisionSelect::Force(Precision::F32),
            ..Default::default()
        });
        assert_eq!(forced32.resolve_precision(&g), Precision::F32);
        // Auto delegates to the dse recommendation (whatever it says for
        // this model, it must be deterministic)
        let auto = Planner::default();
        assert_eq!(auto.resolve_precision(&g), auto.resolve_precision(&g));
    }
}
