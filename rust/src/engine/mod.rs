//! End-to-end generator engine: ahead-of-time plan compilation + parallel
//! whole-model execution (the system's native, PJRT-free serving backend).
//!
//! The seed's functional simulator executed one DeConv layer at a time and
//! re-derived phase filters, Winograd filter transforms and reordered
//! layouts on every call. This subsystem splits that work the way the
//! paper's methodology (and the TDC/fast-algorithm literature) dictates:
//!
//! * **Compile once** ([`plan`]): a [`Planner`] lowers a `gan::zoo` model
//!   into per-layer [`LayerPlan`]s — TDC phase decomposition, Winograd
//!   `G g Gᵀ` filter transforms with vector-level sparsity reordering,
//!   per-layer method selection raced through the `dse` cycle model, and
//!   fixed line-buffer geometry.
//! * **Execute many** ([`exec`]): an [`Engine`] chains the whole generator
//!   with activation hand-off between layers, two-level (sample × stripe)
//!   scheduling on a persistent [`WorkerPool`] ([`pool`]), and per-layer
//!   [`Events`] aggregation that matches the seed's line-buffered
//!   functional simulator exactly. Wide batches dispatch one pool task per
//!   sample ([`BatchSchedule::SampleLevel`]); single requests and narrow
//!   batches split every layer across output stripes
//!   ([`BatchSchedule::StripeLevel`]). The Winograd datapath executes each
//!   stripe as one **tile-batched Winograd-domain GEMM**
//!   ([`crate::winograd::layout::engine_multiply_batch`]) over blocking
//!   geometry precompiled on the plan ([`plan::TileGeometry`]), with every
//!   intermediate buffer drawn from reusable per-worker **scratch arenas**
//!   ([`scratch`], [`pool::ScratchStash`]) — zero per-tile heap
//!   allocations, filter data streamed once per stripe instead of once per
//!   tile, bit-identical outputs.
//! * **Serve** ([`serve`]): a [`NativeRuntime`] exposing compiled engines
//!   behind the coordinator's artifact-manifest contract, so generation
//!   requests batch and execute through precompiled plans — every route's
//!   engine drawing from **one shared worker pool** sized once at startup
//!   ([`pool::resolve_workers`]), never spawning threads on the request
//!   path.
//!
//! Numerics contract: plans forced to the TDC method are **bit-identical
//! (f64)** to [`reference_forward`], the layer-by-layer composition of the
//! `tdc` standard-DeConv reference; Winograd-method plans agree with it to
//! rounding (≈1e-12 relative) and are bitwise-stable across worker counts.
//!
//! [`Events`]: crate::accel::functional::Events

pub mod exec;
pub mod plan;
pub mod pool;
pub mod scratch;
pub mod serve;

pub use exec::{BatchSchedule, Engine, EngineRun};
pub use plan::{LayerPlan, ModelPlan, PlanOptions, Planner, Select, TileGeometry};
pub use pool::{resolve_workers, ScratchStash, WorkerPool};
pub use scratch::Scratch;
pub use serve::{model_id, native_manifest, NativeConfig, NativeRuntime};

use crate::gan::zoo::Kind;
use crate::tdc;
use crate::util::tensor::Tensor3;

/// The layer-composed standard-DeConv reference: every deconv layer through
/// `tdc::tdc_deconv`, every conv layer through `tdc::conv2d`, chained in
/// plan order. This is the ground truth the engine is pinned against.
pub fn reference_forward(plan: &ModelPlan, x: &Tensor3) -> Tensor3 {
    let mut cur = x.clone();
    for lp in &plan.layers {
        let l = &lp.layer;
        cur = match l.kind {
            Kind::Deconv => tdc::tdc_deconv(&cur, &lp.weights, l.s, l.p),
            Kind::Conv => tdc::conv2d(&cur, &lp.weights, l.s, l.p),
        };
    }
    cur
}
