//! End-to-end generator engine: ahead-of-time plan compilation + parallel
//! whole-model execution (the system's native, PJRT-free serving backend).
//!
//! The seed's functional simulator executed one DeConv layer at a time and
//! re-derived phase filters, Winograd filter transforms and reordered
//! layouts on every call. This subsystem splits that work the way the
//! paper's methodology (and the TDC/fast-algorithm literature) dictates:
//!
//! * **Compile once** ([`plan`]): a [`Planner`] lowers a `gan::zoo` model
//!   into per-layer [`LayerPlan`]s — TDC phase decomposition, Winograd
//!   `G g Gᵀ` filter transforms with vector-level sparsity reordering,
//!   per-layer method selection raced through the `dse` cycle model, and
//!   fixed line-buffer geometry. Compilation always runs at `f64`; the
//!   compiled plan is then **lowered to a precision tier**
//!   ([`ModelPlan::lower`], [`Precision`]) — the tier is picked per plan
//!   by the `dse` bandwidth analysis
//!   ([`crate::dse::recommend_precision`]) and overridable end to end
//!   ([`NativeConfig::precision`], `wingan serve --precision`,
//!   the [`plan::PRECISION_ENV`] environment variable).
//! * **Execute many** ([`exec`]): an [`Engine`]`<E>` — generic over the
//!   plan's element precision — chains the whole generator with
//!   activation hand-off between layers (`gan::zoo::Activation`:
//!   ReLU/leaky-ReLU hidden layers, `tanh` outputs), two-level
//!   (sample × stripe) scheduling on a persistent [`WorkerPool`]
//!   ([`pool`]), and per-layer [`Events`] aggregation that matches the
//!   seed's line-buffered functional simulator exactly. Wide batches
//!   dispatch one pool task per sample ([`BatchSchedule::SampleLevel`]);
//!   single requests and narrow batches split every layer across output
//!   stripes ([`BatchSchedule::StripeLevel`]). The Winograd datapath
//!   executes each stripe as one **register/cache-blocked tile-batched
//!   Winograd-domain GEMM** ([`crate::winograd::kernel::multiply_batch`])
//!   over blocking geometry precompiled on the plan
//!   ([`plan::TileGeometry`]), dispatched to the **micro-kernel compiled
//!   into the plan** ([`plan::KernelSelect`], [`KernelKind`]: explicit
//!   AVX2/NEON SIMD or the blocked scalar fallback, with runtime zero-skip
//!   over the slabs' dead `c_in` runs), with every intermediate buffer
//!   drawn from reusable per-worker **scratch arenas** ([`scratch`],
//!   [`pool::ScratchStash`]) — zero per-tile heap allocations, filter data
//!   streamed once per stripe instead of once per tile.
//! * **Serve** ([`serve`]): a [`NativeRuntime`] exposing compiled engines
//!   behind the coordinator's artifact-manifest contract, so generation
//!   requests batch and execute through precompiled plans — every route's
//!   engine drawing from **one shared worker pool** sized once at startup
//!   ([`pool::resolve_workers`]), never spawning threads on the request
//!   path. Fast routes hold an [`AnyEngine`] at the resolved precision
//!   (the **f32 serving fast path** keeps request buffers in single
//!   precision end to end); the `"tdc"` reference routes always serve
//!   `f64`. With a [`NativeConfig::plan_store`], route plans load from
//!   on-disk artifacts ([`crate::artifact`]) instead of compiling at
//!   startup — cold start becomes a file read, with in-process compilation
//!   (plus publish-back) as the fallback.
//!
//! Numerics contract: plans forced to the TDC method are **bit-identical
//! (f64)** to [`reference_forward`], the layer-by-layer composition of the
//! `tdc` standard-DeConv reference; Winograd-method plans agree with it to
//! rounding (≈1e-12 relative) — and **f32 plans agree with the f64
//! reference to single-precision rounding** while staying bitwise-stable
//! across worker counts and schedules, exactly like `f64` plans.
//!
//! [`Events`]: crate::accel::functional::Events

pub mod exec;
pub mod plan;
pub mod pool;
pub mod scratch;
pub mod serve;

pub use crate::util::elem::{Elem, Precision};
pub use crate::winograd::kernel::{simd_available, KernelKind};
pub use exec::{AnyEngine, BatchSchedule, Engine, EngineRun};
pub use plan::{
    resolve_kernel, resolve_precision, KernelSelect, LayerPlan, ModelPlan, PlanOptions, Planner,
    PrecisionSelect, Select, TileGeometry, KERNEL_ENV, PRECISION_ENV,
};
pub use pool::{resolve_workers, ScratchStash, WorkerPool};
pub use scratch::Scratch;
pub use serve::{model_id, native_manifest, NativeConfig, NativeRuntime, ROUTE_METHODS};

use crate::gan::zoo::Kind;
use crate::tdc;
use crate::util::tensor::Tensor3;

/// The layer-composed standard-DeConv reference: every deconv layer through
/// `tdc::tdc_deconv`, every conv layer through `tdc::conv2d`, each followed
/// by the layer's hand-off activation, chained in plan order. This is the
/// ground truth the engine is pinned against, at either precision (the
/// bit-identity contract is stated at `f64`; the `f32` tier carries a
/// tolerance contract against the *f64* reference).
pub fn reference_forward<E: Elem>(plan: &ModelPlan<E>, x: &Tensor3<E>) -> Tensor3<E> {
    let mut cur = x.clone();
    for lp in &plan.layers {
        let l = &lp.layer;
        cur = match l.kind {
            Kind::Deconv => tdc::tdc_deconv(&cur, &lp.weights, l.s, l.p),
            Kind::Conv => tdc::conv2d(&cur, &lp.weights, l.s, l.p),
        };
        l.act.apply(&mut cur);
    }
    cur
}
