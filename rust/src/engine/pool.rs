//! Stripe/tile worker pool: chunked parallel execution over row ranges.
//!
//! The engine parallelises each layer across *output stripes* (tile rows
//! for the Winograd dataflow, output rows for the TDC datapath). Every
//! stripe's pixels are computed entirely by one worker with a fixed
//! per-pixel accumulation order, so results are bitwise independent of the
//! worker count — parallelism never perturbs numerics.
//!
//! Scoped threads (`std::thread::scope`) keep this dependency-free and let
//! workers borrow the plan + input without `Arc` plumbing.

/// Split `0..n` into at most `workers` contiguous chunks and run `f(start,
/// end)` for each, in parallel. Results come back in chunk order (ascending
/// `start`). `workers <= 1` or `n <= 1` runs inline on the caller's thread.
pub fn run_chunked<T: Send>(
    workers: usize,
    n: usize,
    f: impl Fn(usize, usize) -> T + Sync,
) -> Vec<T> {
    if n == 0 {
        return Vec::new();
    }
    let n_chunks = workers.max(1).min(n);
    if n_chunks == 1 {
        return vec![f(0, n)];
    }
    // near-equal chunks: the first `rem` chunks get one extra stripe
    let base = n / n_chunks;
    let rem = n % n_chunks;
    let mut bounds = Vec::with_capacity(n_chunks);
    let mut start = 0;
    for i in 0..n_chunks {
        let len = base + usize::from(i < rem);
        bounds.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n);

    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = bounds
            .iter()
            .skip(1)
            .map(|&(s, e)| scope.spawn(move || f(s, e)))
            .collect();
        // the caller's thread takes the first chunk instead of idling
        let (s0, e0) = bounds[0];
        let first = f(s0, e0);
        let mut out = Vec::with_capacity(n_chunks);
        out.push(first);
        for h in handles {
            out.push(h.join().expect("engine worker panicked"));
        }
        out
    })
}

/// Default worker count: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_range_in_order() {
        for workers in [1, 2, 3, 7, 64] {
            for n in [0usize, 1, 2, 5, 16] {
                let chunks = run_chunked(workers, n, |s, e| (s, e));
                let mut expect = 0;
                for (s, e) in &chunks {
                    assert_eq!(*s, expect, "workers={workers} n={n}");
                    assert!(e > s);
                    expect = *e;
                }
                assert_eq!(expect, n, "workers={workers} n={n}");
                assert!(chunks.len() <= workers.max(1));
            }
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<u64> = (0..1000).collect();
        let serial: u64 = data.iter().sum();
        let chunks = run_chunked(4, data.len(), |s, e| data[s..e].iter().sum::<u64>());
        assert_eq!(chunks.iter().sum::<u64>(), serial);
    }
}
