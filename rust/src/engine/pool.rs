//! Persistent stripe/tile worker pool: chunked parallel execution over row
//! ranges on long-lived threads.
//!
//! PR 1 parallelised each layer with `std::thread::scope`, spawning fresh
//! OS threads *per phase per layer per request*. That is correct but pays
//! thread-creation latency on every hot-path call — measurable once a
//! server pushes many requests through many layers (see
//! `benches/hotpath.rs`, "spawn-overhead elimination"). This module
//! replaces it with a [`WorkerPool`]: threads are spawned once, fed through
//! a channel-backed task queue, and reused for every subsequent dispatch.
//! One pool is shared by every engine of a native server
//! ([`crate::engine::NativeRuntime`]), so concurrent requests contend for
//! the same fixed set of cores instead of oversubscribing the machine.
//!
//! # Scope-safe dispatch
//!
//! [`WorkerPool::run_chunked`] lets tasks borrow the caller's stack (the
//! plan, the input tensor) without `Arc` plumbing, exactly like the scoped
//! threads it replaces: the call does not return — by value, panic, or pool
//! shutdown — until every task it queued has either finished or been
//! destroyed unexecuted, so the borrows can never dangle. Internally that
//! is one carefully-guarded lifetime erasure at the queue boundary; see the
//! `SAFETY` comment in the source.
//!
//! # Scratch-carrying dispatch
//!
//! [`WorkerPool::run_chunked_with`] pairs every chunk with a reusable
//! scratch arena checked out of a [`ScratchStash`] — the engine's way of
//! keeping the Winograd hot loop free of per-tile allocations: transform
//! buffers, gathered-tile matrices and accumulators grown by one stripe
//! task are handed to the next task (and the next request) instead of
//! being reallocated.
//!
//! # Numerics
//!
//! Every stripe's pixels are computed entirely by one task with a fixed
//! per-pixel accumulation order, and results are returned in chunk order
//! (ascending `start`), so results are **bitwise independent of the worker
//! count and of scheduling** — parallelism never perturbs numerics. The
//! engine's two batch schedules lean on the same property (see
//! [`crate::engine::BatchSchedule`]).
//!
//! # Sizing
//!
//! Pool sizing is resolved in exactly one place, [`resolve_workers`]:
//! an explicit request (CLI `--workers`, [`NativeConfig::workers`]) wins,
//! then the `WINGAN_WORKERS` environment variable, then one thread per
//! available core.
//!
//! # Fault isolation
//!
//! A panicking chunk is caught on the worker, reported to its dispatcher,
//! and re-raised there after every sibling chunk is accounted for — the
//! worker thread itself survives, and so does the dispatch protocol. The
//! pool's internal locks are taken through
//! [`lock_unpoisoned`](crate::util::lock_unpoisoned), so a panic while
//! holding one cannot brick every other route sharing the pool. For
//! deterministic chaos testing, [`WorkerPool::set_fault_plane`] installs a
//! [`crate::faultinject::FaultPlane`] whose `worker_chunk` site fires
//! panics/delays inside chunk tasks; when no plane is installed the hot
//! path pays one relaxed atomic load per dispatch.
//!
//! [`NativeConfig::workers`]: crate::engine::NativeConfig#structfield.workers

use crate::faultinject::{FaultAction, FaultPlane, FaultSite};
use crate::util::lock_unpoisoned;
use std::any::Any;
use std::cell::Cell;
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A type-erased unit of work on the pool's queue.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Unique id per pool instance, for worker-reentrancy detection.
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Id of the pool this thread is a worker of (0 = not a pool worker).
    static WORKER_OF: Cell<u64> = const { Cell::new(0) };
}

/// Environment variable consulted by [`resolve_workers`] when no explicit
/// worker count is requested.
pub const WORKERS_ENV: &str = "WINGAN_WORKERS";

/// The single source of truth for pool sizing (the `default_workers`
/// duplication of PR 1 lived in `engine/exec.rs` *and* `engine/serve.rs`;
/// both now route here). Resolution order:
///
/// 1. `requested`, when non-zero (an explicit CLI `--workers` flag or
///    config field);
/// 2. the [`WORKERS_ENV`] environment variable, when it parses as an
///    integer — `WINGAN_WORKERS=0` is clamped to one worker with a logged
///    correction (a zero-worker pool can never run anything);
/// 3. one worker per available core.
pub fn resolve_workers(requested: usize) -> usize {
    resolve_with(requested, std::env::var(WORKERS_ENV).ok())
}

/// [`resolve_workers`] with the environment injected, so the precedence
/// rules are testable without mutating process-global state.
fn resolve_with(requested: usize, env: Option<String>) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Some(v) = env {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
            eprintln!("wingan: {WORKERS_ENV}=0 is not a valid pool size; using 1 worker");
            return 1;
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A fixed-size pool of long-lived worker threads fed by a channel-backed
/// task queue.
///
/// Construction spawns the threads once ([`WorkerPool::new`], or
/// [`WorkerPool::shared`] for the usual `Arc`-wrapped form); dispatch
/// ([`WorkerPool::run_chunked`]) queues borrowed closures and blocks the
/// caller until its tasks complete, with the caller's own thread executing
/// the first chunk instead of idling. Dropping the pool closes the queue
/// and joins every worker.
pub struct WorkerPool {
    /// `None` once shutdown has begun; closing the sender ends the workers.
    tx: Mutex<Option<Sender<Job>>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    threads: usize,
    /// unique per pool; workers tag themselves with it (reentrancy guard)
    id: u64,
    /// deterministic fault-injection plane (`worker_chunk` site); `None`
    /// in production
    faults: Mutex<Option<Arc<FaultPlane>>>,
    /// fast-path flag so undisturbed dispatches never touch the mutex
    faults_set: AtomicBool,
}

impl WorkerPool {
    /// Spawn a pool of `threads.max(1)` long-lived workers.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let id = NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("wingan-pool-{i}"))
                    .spawn(move || {
                        WORKER_OF.with(|w| w.set(id));
                        worker_loop(&rx)
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            tx: Mutex::new(Some(tx)),
            handles: Mutex::new(handles),
            threads,
            id,
            faults: Mutex::new(None),
            faults_set: AtomicBool::new(false),
        }
    }

    /// Install (or clear) a deterministic fault-injection plane. Chunk
    /// tasks consult the plane's `worker_chunk` site: a firing rule panics
    /// inside the chunk (contained and re-raised by the dispatcher, like
    /// any real chunk bug) or delays it. Production servers never call
    /// this; `wingan chaos` and the chaos tests do.
    pub fn set_fault_plane(&self, plane: Option<Arc<FaultPlane>>) {
        let set = plane.is_some();
        *lock_unpoisoned(&self.faults) = plane;
        self.faults_set.store(set, Ordering::Release);
    }

    /// `Arc`-wrapped pool, ready to share across engines (one pool serves
    /// every route of a native server).
    pub fn shared(threads: usize) -> Arc<WorkerPool> {
        Arc::new(WorkerPool::new(threads))
    }

    /// Number of worker threads (fixed at construction).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `0..n` into at most `max_chunks` contiguous chunks and run
    /// `f(start, end)` for each, in parallel on the pool. Results come back
    /// in chunk order (ascending `start`). `max_chunks <= 1` or `n <= 1`
    /// runs inline on the caller's thread; otherwise the caller executes
    /// the first chunk itself and pool workers take the rest.
    ///
    /// `f` may borrow freely from the caller's stack: the call blocks until
    /// every queued task has run (or been destroyed by pool shutdown), and
    /// a panic inside any chunk is re-raised here — after all sibling
    /// chunks have been accounted for, never before.
    ///
    /// **Reentrancy**: dispatching from a thread that is itself a worker of
    /// this pool would deadlock (the dispatcher blocks a worker slot while
    /// its sub-tasks wait behind it in the queue), so that case is detected
    /// and runs the whole range inline as one chunk instead — results stay
    /// bitwise identical, since chunking never affects numerics.
    pub fn run_chunked<T: Send>(
        &self,
        max_chunks: usize,
        n: usize,
        f: impl Fn(usize, usize) -> T + Sync,
    ) -> Vec<T> {
        if n == 0 {
            return Vec::new();
        }
        // fault hook (chaos testing): fetched once per dispatch; a firing
        // `worker_chunk` rule panics or delays inside the chunk task, so
        // it exercises exactly the containment path a real chunk bug would
        let plane = if self.faults_set.load(Ordering::Acquire) {
            lock_unpoisoned(&self.faults).clone()
        } else {
            None
        };
        let run = |s: usize, e: usize| {
            if let Some(p) = &plane {
                match p.check(FaultSite::WorkerChunk) {
                    Some(FaultAction::Delay(d)) => std::thread::sleep(d),
                    Some(_) => panic!("fault injected: worker_chunk panic"),
                    None => {}
                }
            }
            f(s, e)
        };
        let n_chunks = max_chunks.max(1).min(n);
        if n_chunks == 1 || WORKER_OF.with(|w| w.get()) == self.id {
            return vec![run(0, n)];
        }
        let bounds = chunk_bounds(n_chunks, n);

        // one queue-lock acquisition per dispatch, not per job (Sender is
        // Clone and send() itself needs no lock here)
        let queue = {
            let tx = lock_unpoisoned(&self.tx);
            tx.as_ref().expect("worker pool used after shutdown").clone()
        };

        // Each queued job sends exactly one message, even when its chunk
        // panics; the drain loop below therefore observes every job.
        let (done_tx, done_rx) = mpsc::channel::<(usize, std::thread::Result<T>)>();
        for (i, &(s, e)) in bounds.iter().enumerate().skip(1) {
            let tx = done_tx.clone();
            let f = &run;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(s, e)));
                let _ = tx.send((i, r));
            });
            // SAFETY: the job borrows `run` (which borrows `f` and the
            // fault plane, and, through `T`, possibly the caller's stack). We erase that lifetime to put it on the
            // 'static queue, which is sound because this function does not
            // return — normally or by unwinding — until each queued job has
            // either completed (its message was received) or been dropped
            // unexecuted by pool shutdown (every `done_tx` clone gone, so
            // `recv` disconnects). In both cases no job can touch the
            // borrow after this frame dies. The caller-side panic path
            // below drains the channel before re-raising for the same
            // reason.
            // (annotated via the two `let` bindings above/below; the
            // turbofish form cannot name the anonymous closure lifetime)
            #[allow(clippy::missing_transmute_annotations)]
            let job: Job = unsafe { std::mem::transmute(job) };
            queue.send(job).expect("worker pool queue closed");
        }
        drop(queue);
        drop(done_tx);

        // the caller's thread takes the first chunk instead of idling
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n_chunks);
        slots.resize_with(n_chunks, || None);
        let mut panicked: Option<Box<dyn Any + Send>> = None;
        match catch_unwind(AssertUnwindSafe(|| run(bounds[0].0, bounds[0].1))) {
            Ok(v) => slots[0] = Some(v),
            Err(p) => panicked = Some(p),
        }
        for _ in 1..n_chunks {
            match done_rx.recv() {
                Ok((i, Ok(v))) => slots[i] = Some(v),
                Ok((_, Err(p))) => panicked = Some(p),
                Err(_) => {
                    // pool shut down and dropped jobs without running them;
                    // nothing outstanding can borrow from this frame anymore
                    panicked = Some(Box::new("worker pool shut down mid-dispatch"));
                    break;
                }
            }
        }
        if let Some(p) = panicked {
            resume_unwind(p);
        }
        slots.into_iter().map(|s| s.expect("missing chunk result")).collect()
    }

    /// [`WorkerPool::run_chunked`] with a per-chunk scratch arena: every
    /// chunk checks an `S` out of `stash`, runs `f(&mut scratch, start,
    /// end)`, and returns the scratch for later chunks (and later
    /// dispatches) to reuse. This is how the engine's hot loops stay free
    /// of per-tile allocations — buffers grown by one stripe task are
    /// handed to the next instead of being dropped.
    ///
    /// Chunking, ordering, panic and reentrancy semantics are exactly those
    /// of [`WorkerPool::run_chunked`]; the scratch is a pure capacity
    /// optimization and must never change results (the engine's
    /// worker-count-invariance tests pin this).
    pub fn run_chunked_with<S: Default + Send, T: Send>(
        &self,
        stash: &ScratchStash<S>,
        max_chunks: usize,
        n: usize,
        f: impl Fn(&mut S, usize, usize) -> T + Sync,
    ) -> Vec<T> {
        self.run_chunked(max_chunks, n, |s, e| {
            let mut scratch = stash.take();
            let out = f(&mut scratch, s, e);
            stash.put(scratch);
            out
        })
    }
}

/// A free-list of reusable per-task scratch arenas.
///
/// [`WorkerPool::run_chunked_with`] checks one scratch out per chunk and
/// returns it when the chunk finishes, so buffers grown by one dispatch are
/// reused by the next — across tiles, phases, layers and requests. The
/// stash never holds more scratches than the peak number of concurrent
/// chunks, and a scratch checked out when a chunk panics is simply dropped
/// (conservative, never corrupting).
///
/// `S` is only required to be [`Default`] (an empty scratch, grown on
/// first use) and `Send` (scratches migrate between worker threads).
pub struct ScratchStash<S> {
    free: Mutex<Vec<S>>,
}

impl<S: Default> ScratchStash<S> {
    /// An empty stash; scratches are created lazily on first checkout.
    pub fn new() -> ScratchStash<S> {
        ScratchStash { free: Mutex::new(Vec::new()) }
    }

    /// Check a scratch out: a previously returned one when available,
    /// otherwise a fresh `S::default()`.
    pub fn take(&self) -> S {
        lock_unpoisoned(&self.free).pop().unwrap_or_default()
    }

    /// Return a scratch for the next task to reuse.
    pub fn put(&self, s: S) {
        lock_unpoisoned(&self.free).push(s);
    }

    /// Number of scratches currently parked in the stash (observability /
    /// tests — the steady state equals the peak concurrent-task count).
    pub fn idle(&self) -> usize {
        lock_unpoisoned(&self.free).len()
    }
}

impl<S: Default> Default for ScratchStash<S> {
    fn default() -> Self {
        ScratchStash::new()
    }
}

impl<S> fmt::Debug for ScratchStash<S> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let idle = lock_unpoisoned(&self.free).len();
        f.debug_struct("ScratchStash").field("idle", &idle).finish()
    }
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.threads).finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // a poisoned queue lock must not leave the sender alive: the
        // workers would block on recv forever and the joins would hang
        lock_unpoisoned(&self.tx).take(); // closing the queue ends every worker
        for h in lock_unpoisoned(&self.handles).drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // hold the lock only while receiving, never while running a job
        let job = {
            let rx = lock_unpoisoned(rx);
            rx.recv()
        };
        match job {
            // a panicking chunk is reported to its dispatcher through the
            // job's own completion channel; the worker itself survives
            Ok(job) => {
                let _ = catch_unwind(AssertUnwindSafe(move || job()));
            }
            Err(_) => return, // queue closed: pool is shutting down
        }
    }
}

/// Split `0..n` into `k` near-equal contiguous `(start, end)` ranges; the
/// first `n % k` chunks get one extra element.
fn chunk_bounds(k: usize, n: usize) -> Vec<(usize, usize)> {
    let base = n / k;
    let rem = n % k;
    let mut bounds = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        bounds.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n);
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_range_in_order() {
        let pool = WorkerPool::new(4);
        for workers in [1, 2, 3, 7, 64] {
            for n in [0usize, 1, 2, 5, 16] {
                let chunks = pool.run_chunked(workers, n, |s, e| (s, e));
                let mut expect = 0;
                for (s, e) in &chunks {
                    assert_eq!(*s, expect, "workers={workers} n={n}");
                    assert!(e > s);
                    expect = *e;
                }
                assert_eq!(expect, n, "workers={workers} n={n}");
                assert!(chunks.len() <= workers.max(1));
            }
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let serial: u64 = data.iter().sum();
        let chunks = pool.run_chunked(4, data.len(), |s, e| data[s..e].iter().sum::<u64>());
        assert_eq!(chunks.iter().sum::<u64>(), serial);
    }

    #[test]
    fn pool_is_reused_across_many_dispatches() {
        let pool = WorkerPool::new(3);
        for round in 0..200u64 {
            let chunks = pool.run_chunked(3, 9, |s, e| (s as u64 + round, e));
            assert_eq!(chunks.len(), 3);
            assert_eq!(chunks[0].0, round);
        }
    }

    #[test]
    fn concurrent_dispatchers_share_one_pool() {
        let pool = WorkerPool::shared(4);
        let data: Vec<u64> = (0..512).collect();
        let serial: u64 = data.iter().sum();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let pool = &pool;
                    let data = &data;
                    s.spawn(move || {
                        let chunks =
                            pool.run_chunked(4, data.len(), |a, b| data[a..b].iter().sum::<u64>());
                        chunks.iter().sum::<u64>()
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), serial);
            }
        });
    }

    #[test]
    #[should_panic(expected = "chunk 2 exploded")]
    fn chunk_panic_propagates_to_dispatcher() {
        let pool = WorkerPool::new(4);
        pool.run_chunked(4, 4, |s, _e| {
            if s == 2 {
                panic!("chunk 2 exploded");
            }
            s
        });
    }

    #[test]
    fn pool_survives_a_panicking_dispatch() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_chunked(2, 2, |s, _e| {
                if s == 1 {
                    panic!("boom");
                }
                s
            })
        }));
        assert!(r.is_err());
        // the workers are still alive and serving
        let chunks = pool.run_chunked(2, 8, |s, e| e - s);
        assert_eq!(chunks.iter().sum::<usize>(), 8);
    }

    #[test]
    fn scratch_stash_reuses_buffers_across_dispatches() {
        let pool = WorkerPool::new(3);
        let stash: ScratchStash<Vec<u64>> = ScratchStash::new();
        let data: Vec<u64> = (0..300).collect();
        let serial: u64 = data.iter().sum();
        for _ in 0..20 {
            let chunks = pool.run_chunked_with(&stash, 3, data.len(), |scratch, s, e| {
                // grow-once buffer: later dispatches find it pre-sized
                scratch.resize(data.len(), 0);
                scratch[s..e].copy_from_slice(&data[s..e]);
                scratch[s..e].iter().sum::<u64>()
            });
            assert_eq!(chunks.iter().sum::<u64>(), serial);
        }
        // every checked-out scratch came back, and no more were ever made
        // than the peak number of concurrent chunks
        assert!(stash.idle() >= 1 && stash.idle() <= 3, "idle = {}", stash.idle());
    }

    #[test]
    fn resolve_workers_precedence() {
        // injected env keeps this test free of process-global mutation
        assert_eq!(resolve_with(5, Some("3".into())), 5, "explicit request wins");
        assert_eq!(resolve_with(0, Some("3".into())), 3, "env fills in for 0");
        assert_eq!(resolve_with(0, Some(" 7 ".into())), 7, "env is trimmed");
        assert!(resolve_with(0, Some("not-a-number".into())) >= 1, "garbage env -> cores");
        assert_eq!(
            resolve_with(0, Some("0".into())),
            1,
            "zero env is clamped to one worker, not silently ignored"
        );
        assert_eq!(resolve_with(0, Some(" 0 ".into())), 1, "trimmed zero env clamps too");
        assert!(resolve_with(0, None) >= 1, "no env -> cores");
        assert!(resolve_workers(0) >= 1, "end-to-end default is at least one worker");
    }

    #[test]
    fn locks_recover_after_a_poisoning_panic() {
        // poison the scratch-stash lock the only way possible: panic while
        // holding it
        let stash: ScratchStash<Vec<u8>> = ScratchStash::new();
        stash.put(vec![1]);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = stash.free.lock().unwrap();
            panic!("poison the stash lock");
        }));
        assert!(stash.free.lock().is_err(), "the mutex really is poisoned");
        assert_eq!(stash.take(), vec![1], "stash still serves after poisoning");

        // same for the pool's queue lock: a poisoned lock must not turn
        // one contained panic into a permanent denial of service
        let pool = WorkerPool::new(2);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = pool.tx.lock().unwrap();
            panic!("poison the queue lock");
        }));
        assert!(pool.tx.lock().is_err(), "the queue lock really is poisoned");
        let chunks = pool.run_chunked(2, 8, |s, e| e - s);
        assert_eq!(chunks.iter().sum::<usize>(), 8, "dispatch survives a poisoned queue lock");
        // Drop must also get through the poisoned lock to close the queue,
        // or the worker joins below would hang the test
    }

    #[test]
    fn worker_chunk_faults_fire_deterministically_then_stop() {
        let pool = WorkerPool::new(2);
        let plane = Arc::new(FaultPlane::parse("seed=7;worker_chunk:panic*2@1").unwrap());
        pool.set_fault_plane(Some(plane.clone()));
        let r = catch_unwind(AssertUnwindSafe(|| pool.run_chunked(2, 4, |s, e| e - s)));
        assert!(r.is_err(), "injected chunk panic must reach the dispatcher");
        assert_eq!(plane.fired_at(FaultSite::WorkerChunk), 2, "both chunks of the burst fired");
        // the burst cap (*2) is exhausted: the pool serves normally again
        let chunks = pool.run_chunked(2, 8, |s, e| e - s);
        assert_eq!(chunks.iter().sum::<usize>(), 8);
        pool.set_fault_plane(None);
        let chunks = pool.run_chunked(2, 8, |s, e| e - s);
        assert_eq!(chunks.iter().sum::<usize>(), 8);
    }

    #[test]
    fn reentrant_dispatch_from_a_worker_runs_inline() {
        // a task running on the pool that (transitively) dispatches to the
        // same pool must not deadlock: the inner dispatch detects it is on
        // a worker thread and runs inline as a single chunk
        let pool = WorkerPool::new(2);
        let outer = pool.run_chunked(2, 2, |s, _e| {
            let inner = pool.run_chunked(4, 8, |a, b| (b - a) as u64);
            (s as u64, inner.iter().sum::<u64>())
        });
        assert_eq!(outer.len(), 2);
        for (_, inner_sum) in outer {
            assert_eq!(inner_sum, 8);
        }
        // a different pool's workers are not "this pool": cross-pool
        // dispatch still parallelises
        let other = WorkerPool::new(2);
        let chunks = pool.run_chunked(2, 4, |s, _e| other.run_chunked(2, 4, |a, b| b - a).len() + s);
        assert_eq!(chunks.len(), 2);
    }
}
