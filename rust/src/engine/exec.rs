//! Whole-generator execution of precompiled plans (the "execute" half of
//! the plan-compile / execute split).
//!
//! The [`Engine`] walks a [`ModelPlan`] layer by layer, handing each
//! layer's activation tensor (through the layer's activation function) to
//! the next, and schedules work on a persistent [`WorkerPool`] at **two
//! levels** ([`BatchSchedule`]):
//!
//! * **stripe-level** — each layer is split across output stripes (tile
//!   rows on the Winograd datapath, output rows on the TDC/conv
//!   datapaths); this is how single requests and narrow batches run;
//! * **sample-level** — a wide batch dispatches one pool task per sample,
//!   each sample executing its layers single-threaded, so whole samples
//!   stream through the workers with no per-layer synchronisation.
//!
//! The engine is **generic over the plan's element precision**
//! ([`Elem`]): `Engine<f64>` is the reference tier, `Engine<f32>` the
//! serving fast path (half the memory traffic on every hot-loop stream,
//! double the SIMD width). [`AnyEngine`] is the runtime-precision handle
//! the serving layer routes through.
//!
//! Each output pixel is produced by exactly one task with a fixed
//! accumulation order under *either* schedule, so the result is **bitwise
//! independent of the worker count and of the schedule at both
//! precisions**, and the TDC datapath is **bit-identical (f64) to the
//! layer-composed standard-DeConv reference**
//! ([`crate::engine::reference_forward`]).
//!
//! Event accounting mirrors `accel::functional` exactly: for a deconv layer
//! the engine's per-layer [`Events`] equal what `run_winograd_deconv` /
//! `run_tdc_deconv` would have measured through the line-buffered dataflow
//! (the tests pin this), without paying the per-call re-derivation the seed
//! simulator did. Event counts are precision-independent.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::accel::functional::Events;
use crate::engine::plan::{LayerPlan, ModelPlan};
use crate::engine::pool::{resolve_workers, ScratchStash, WorkerPool};
use crate::engine::scratch::Scratch;
use crate::gan::workload::Method;
use crate::gan::zoo::Kind;
use crate::tdc;
use crate::telemetry::{self, Stage, TraceId};
use crate::util::elem::{Elem, Precision};
use crate::util::tensor::Tensor3;
use crate::winograd::kernel::multiply_batch;
use crate::winograd::transforms::{input_transform, inverse_transform, Tile4, M, N};

/// Result of running one model through the engine.
#[derive(Debug)]
pub struct EngineRun<E: Elem = f64> {
    pub y: Tensor3<E>,
    /// measured events per layer, in layer order
    pub per_layer: Vec<Events>,
    /// aggregate over all layers
    pub events: Events,
    /// wall-clock execution time for this run
    pub elapsed: Duration,
}

/// How [`Engine::run_batch`] schedules a batch on the worker pool. Both
/// schedules produce bitwise-identical outputs and event counts; they
/// differ only in which axis feeds the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchSchedule {
    /// One pool task per sample; each sample executes its layers inline
    /// (single-threaded). Chosen when the batch is wide enough to keep
    /// every worker busy on whole samples — no per-layer barrier, better
    /// cache locality per worker.
    SampleLevel,
    /// Samples run one after another, each layer split across output
    /// stripes on the pool. Chosen for narrow batches, where sample-level
    /// dispatch would leave workers idle.
    StripeLevel,
}

/// Executes precompiled [`ModelPlan`]s with two-level (sample × stripe)
/// parallelism on a persistent [`WorkerPool`], at the plan's element
/// precision.
///
/// Engines are cheap to clone (the plan and pool are shared behind `Arc`s)
/// and may share one pool via [`Engine::with_pool`] — the configuration a
/// native server uses so every route's requests draw from one fixed set of
/// worker threads.
#[derive(Clone, Debug)]
pub struct Engine<E: Elem = f64> {
    plan: Arc<ModelPlan<E>>,
    pool: Arc<WorkerPool>,
    /// reusable per-task buffers, shared by every clone of this engine so
    /// scratch grown by one request is reused by the next
    scratch: Arc<ScratchStash<Scratch<E>>>,
}

impl<E: Elem> Engine<E> {
    /// Private pool sized by [`resolve_workers`]`(0)`: one worker per core
    /// unless the `WINGAN_WORKERS` environment variable overrides it.
    ///
    /// All constructors take `impl Into<Arc<ModelPlan<E>>>`: pass an owned
    /// [`ModelPlan`] to wrap it, or an `Arc<ModelPlan<E>>` to share one
    /// compiled plan across many engines without deep-cloning it.
    pub fn new(plan: impl Into<Arc<ModelPlan<E>>>) -> Engine<E> {
        Engine::with_pool(plan, WorkerPool::shared(resolve_workers(0)))
    }

    /// Private pool with exactly `workers.max(1)` threads.
    pub fn with_workers(plan: impl Into<Arc<ModelPlan<E>>>, workers: usize) -> Engine<E> {
        Engine::with_pool(plan, WorkerPool::shared(workers.max(1)))
    }

    /// Execute on an existing (typically shared) pool.
    pub fn with_pool(plan: impl Into<Arc<ModelPlan<E>>>, pool: Arc<WorkerPool>) -> Engine<E> {
        Engine { plan: plan.into(), pool, scratch: Arc::new(ScratchStash::new()) }
    }

    /// The compiled plan this engine executes.
    pub fn plan(&self) -> &ModelPlan<E> {
        &self.plan
    }

    /// Shared handle to the compiled plan — hand this to another engine's
    /// constructor to execute the same plan without recompiling or
    /// deep-cloning it.
    pub fn plan_arc(&self) -> Arc<ModelPlan<E>> {
        self.plan.clone()
    }

    /// The precision tier this engine executes at.
    pub fn precision(&self) -> Precision {
        E::PRECISION
    }

    /// The worker pool this engine dispatches to.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Worker-thread count of the underlying pool.
    pub fn workers(&self) -> usize {
        self.pool.threads()
    }

    /// Run the whole generator on one input activation tensor,
    /// stripe-parallel across the full pool.
    pub fn run(&self, x: &Tensor3<E>) -> EngineRun<E> {
        self.run_with_chunks(x, self.pool.threads())
    }

    /// Run one sample, splitting every layer into at most `chunks` stripe
    /// ranges (`chunks == 1` executes inline on the calling thread).
    ///
    /// The first layer borrows `x` directly (no per-request input copy);
    /// one [`Scratch`] is checked out for the whole run and reused across
    /// every phase and layer for the padded-input views.
    fn run_with_chunks(&self, x: &Tensor3<E>, chunks: usize) -> EngineRun<E> {
        let t0 = Instant::now();
        assert_eq!(
            (x.c, x.h, x.w),
            self.plan.input_shape,
            "engine input shape mismatch for {}",
            self.plan.model
        );
        let mut scratch = self.scratch.take();
        let mut cur: Option<Tensor3<E>> = None;
        let mut per_layer = Vec::with_capacity(self.plan.layers.len());
        let mut total = Events::default();
        // the trace id rides the thread-local set by the coordinator's
        // dispatch path (telemetry::with_trace); 0 = untraced, and every
        // timing site below is guarded on it, so the untraced hot path
        // pays a branch per layer, never a clock read
        let trace = telemetry::current_trace();
        for (li, lp) in self.plan.layers.iter().enumerate() {
            let (y, ev) =
                self.run_layer(lp, cur.as_ref().unwrap_or(x), chunks, &mut scratch, trace, li);
            total.merge(&ev);
            per_layer.push(ev);
            cur = Some(y);
        }
        self.scratch.put(scratch);
        let y = cur.unwrap_or_else(|| x.clone());
        EngineRun { y, per_layer, events: total, elapsed: t0.elapsed() }
    }

    /// Scheduling decision for a batch of `batch` samples: sample-level
    /// once the batch alone can occupy every pool thread, stripe-level
    /// otherwise (including the single-threaded pool, where there is
    /// nothing to win from sample dispatch).
    pub fn batch_schedule(&self, batch: usize) -> BatchSchedule {
        if self.pool.threads() > 1 && batch >= self.pool.threads() {
            BatchSchedule::SampleLevel
        } else {
            BatchSchedule::StripeLevel
        }
    }

    /// Run a batch of samples under the automatically chosen
    /// [`BatchSchedule`]. Outputs (and event counts) are bitwise identical
    /// under either schedule, in sample order.
    pub fn run_batch(&self, xs: &[Tensor3<E>]) -> Vec<EngineRun<E>> {
        self.run_batch_with(xs, self.batch_schedule(xs.len()))
    }

    /// Run a batch under an explicit schedule (benchmarks and the
    /// schedule-equivalence tests force both paths).
    pub fn run_batch_with(&self, xs: &[Tensor3<E>], schedule: BatchSchedule) -> Vec<EngineRun<E>> {
        match schedule {
            BatchSchedule::StripeLevel => xs.iter().map(|x| self.run(x)).collect(),
            // one chunk per sample normally; honoring the full (s, e) range
            // keeps this correct under the pool's reentrancy fallback, which
            // may hand the whole batch to one inline chunk. The dispatching
            // thread's trace context is re-established inside each pool
            // task so per-layer spans still attach to the request's trace.
            BatchSchedule::SampleLevel => {
                let trace = telemetry::current_trace();
                self.pool
                    .run_chunked(xs.len(), xs.len(), |s, e| {
                        telemetry::with_trace(trace, || {
                            xs[s..e].iter().map(|x| self.run_with_chunks(x, 1)).collect::<Vec<_>>()
                        })
                    })
                    .into_iter()
                    .flatten()
                    .collect()
            }
        }
    }

    /// Each datapath applies the layer's hand-off activation *inside* its
    /// parallel stripe tasks (on the task-local `part` buffer, before the
    /// merge), so the activation sweep is parallel and cache-warm instead
    /// of a second serial full-tensor pass. Every output pixel is produced
    /// by exactly one task and the activation is elementwise, so this is
    /// bitwise identical to activating the assembled output —
    /// worker-count/schedule invariance is untouched, and
    /// [`crate::engine::reference_forward`] applies the same function.
    /// The Winograd datapath reports the four per-layer telemetry stages
    /// (input transform / GEMM / inverse transform / activation) itself;
    /// the TDC and conv datapaths get a single whole-layer
    /// [`Stage::LayerExec`] span. `trace == 0` (the untraced fast path)
    /// skips every clock read.
    fn run_layer(
        &self,
        lp: &LayerPlan<E>,
        x: &Tensor3<E>,
        chunks: usize,
        scratch: &mut Scratch<E>,
        trace: TraceId,
        li: usize,
    ) -> (Tensor3<E>, Events) {
        let mark = (trace != 0).then(Instant::now);
        let out = match lp.layer.kind {
            Kind::Conv => self.run_conv(lp, x, chunks, scratch),
            Kind::Deconv => match lp.method {
                Method::Winograd => {
                    return self.run_deconv_winograd(lp, x, chunks, scratch, trace, li)
                }
                _ => self.run_deconv_tdc(lp, x, chunks, scratch),
            },
        };
        if let Some(t) = mark {
            telemetry::record_span(
                trace, Stage::LayerExec, t, t.elapsed(), li as u64, 0, &self.plan.model,
            );
        }
        out
    }

    /// TDC datapath: S² phase correlations over phase-padded inputs.
    /// Per-pixel accumulation order matches `tdc::correlate_valid`, so the
    /// output is bit-identical to `tdc::tdc_deconv` regardless of workers.
    /// The phase-padded view is materialized into the run's scratch arena,
    /// reused across phases and layers.
    fn run_deconv_tdc(
        &self,
        lp: &LayerPlan<E>,
        x: &Tensor3<E>,
        n_chunks: usize,
        scratch: &mut Scratch<E>,
    ) -> (Tensor3<E>, Events) {
        let l = &lp.layer;
        let (s, kc) = (l.s, lp.kc);
        let mut y = Tensor3::zeros(l.c_out, s * x.h, s * x.w);
        let mut ev = Events::default();
        for (idx, ph) in lp.phases.iter().enumerate() {
            let (py, px) = (idx / s, idx % s);
            tdc::phase_pad_into(x, ph.d0y, ph.d0x, kc, &mut scratch.xp);
            let xp = &scratch.xp;
            let chunks = self.pool.run_chunked(n_chunks, x.h, |oy_s, oy_e| {
                let mut part = Tensor3::zeros(l.c_out, oy_e - oy_s, x.w);
                let mut pev = Events::default();
                for co in 0..l.c_out {
                    for oy in oy_s..oy_e {
                        for ox in 0..x.w {
                            let mut acc = E::ZERO;
                            for ci in 0..xp.c {
                                for ky in 0..kc {
                                    for kx in 0..kc {
                                        acc += xp.at(ci, oy + ky, ox + kx)
                                            * ph.g.at(ci, co, ky, kx);
                                    }
                                }
                            }
                            *part.at_mut(co, oy - oy_s, ox) = acc;
                        }
                    }
                }
                pev.mults += (l.c_out * (oy_e - oy_s) * x.w * xp.c * kc * kc) as u64;
                pev.stripes += (oy_e - oy_s) as u64;
                // hand-off activation on the task-local buffer (see
                // run_layer) — only once, on the phase that owns the pixel
                l.act.apply(&mut part);
                (part, pev)
            });
            let mut oy_base = 0;
            for (part, pev) in chunks {
                for co in 0..l.c_out {
                    for r in 0..part.h {
                        let oy = oy_base + r;
                        for ox in 0..x.w {
                            *y.at_mut(co, s * oy + py, s * ox + px) = part.at(co, r, ox);
                        }
                    }
                }
                oy_base += part.h;
                ev.merge(&pev);
            }
            // line-buffer model (matches accel::functional::run_tdc_deconv):
            // every issued multiply reads one buffered activation word, and
            // the buffer ingests kc prologue rows + one row per stripe
            ev.linebuf_reads += (l.c_out * x.h * x.w * xp.c * kc * kc) as u64;
            ev.linebuf_writes += ((x.h + kc - 1) * xp.c * xp.w) as u64;
        }
        (y, ev)
    }

    /// Winograd datapath, stripe-batched: precompiled reordered filters,
    /// pre-PE transforms *gathered* across all `tiles_w` tiles of a stripe
    /// into one position-major Winograd-domain matrix, one blocked com-PE
    /// GEMM per stripe over live rows only
    /// ([`crate::winograd::kernel::multiply_batch`], dispatched to the
    /// micro-kernel compiled into the plan's [`TileGeometry`] — the filter
    /// slab is streamed once per stripe instead of once per tile, with
    /// register/cache blocking and runtime zero-skip inside the kernel),
    /// post-PE inverse transform, phase interleave. The per-output
    /// accumulation order is exactly the per-tile path's, so the result is
    /// bit-identical to `accel::functional::run_winograd_deconv` (at f64)
    /// and the [`Events`] counters are unchanged on dense slabs. Empty
    /// (degenerate zero-tap) phases are skipped outright. All intermediate
    /// buffers live in per-worker [`Scratch`] arenas — the tile loop
    /// performs no heap allocation.
    ///
    /// [`TileGeometry`]: crate::engine::plan::TileGeometry
    fn run_deconv_winograd(
        &self,
        lp: &LayerPlan<E>,
        x: &Tensor3<E>,
        n_chunks: usize,
        scratch: &mut Scratch<E>,
        trace: TraceId,
        li: usize,
    ) -> (Tensor3<E>, Events) {
        let l = &lp.layer;
        let s = l.s;
        let mut y = Tensor3::zeros(l.c_out, s * x.h, s * x.w);
        let mut ev = Events::default();
        // per-stage µs accumulated across every stripe task of every phase
        // (gather / GEMM / inverse / activation); clocks only tick for a
        // traced request — the timing never touches the arithmetic, so
        // outputs and Events stay bit-identical tracing on or off
        let trc = trace != 0;
        let t_layer = trc.then(Instant::now);
        let mut stage_us = [0u64; 4];

        // blocking geometry precompiled on the plan (matches the runtime
        // input by the engine's shape contract)
        let geo = lp.tiles;
        debug_assert_eq!((x.h, x.w), (l.h_in, l.w_in), "layer chain geometry");
        debug_assert_eq!((geo.ho_t, geo.wo_t), (x.h.div_ceil(M) * M, x.w.div_ceil(M) * M));
        let tiles_w = geo.tiles_w;

        for (idx, rf) in lp.reordered.iter().enumerate() {
            if rf.live.is_empty() {
                // degenerate zero-tap phase: its sub-filter is identically
                // zero, so the phase's output samples stay at the
                // pre-zeroed y (every zoo activation fixes zero exactly) —
                // no transforms, no GEMM, no line-buffer traffic
                continue;
            }
            let ph = &lp.phases[idx];
            let (py, px) = (idx / s, idx % s);
            // same phase-padded, tile-aligned view the functional simulator
            // reads through its line buffers — shared helper keeps the two
            // datapaths bit-identical by construction; materialized into
            // the run's scratch, not a fresh tensor per phase
            crate::accel::functional::phase_padded_into(x, ph, geo.ho_t, geo.wo_t, &mut scratch.xp);
            let xp = &scratch.xp;

            let chunks = self.pool.run_chunked_with(
                &self.scratch,
                n_chunks,
                geo.tiles_h,
                |scr: &mut Scratch<E>, ty_s, ty_e| {
                    let mut part = Tensor3::zeros(l.c_out, M * (ty_e - ty_s), geo.wo_t);
                    let mut pev = Events::default();
                    let mut us = [0u64; 4];
                    let c_in = xp.c;
                    scr.ensure_winograd(c_in, l.c_out, tiles_w);
                    for ty in ty_s..ty_e {
                        pev.stripes += 1;
                        let mut mark = trc.then(Instant::now);
                        // pre-PE gather: window select + B^T Z B + n² x N
                        // reorder for every tile of the stripe, laid out
                        // position-major [pos][c_in][tiles_w]
                        for tx in 0..tiles_w {
                            pev.tiles += 1;
                            for ci in 0..c_in {
                                let mut z: Tile4<E> = [[E::ZERO; N]; N];
                                for (i, row) in z.iter_mut().enumerate() {
                                    for (j, val) in row.iter_mut().enumerate() {
                                        *val = xp.at(ci, M * ty + i, M * tx + j);
                                    }
                                }
                                let vt = input_transform(&z);
                                for (i, row) in vt.iter().enumerate() {
                                    for (j, val) in row.iter().enumerate() {
                                        scr.v[((i * N + j) * c_in + ci) * tiles_w + tx] = *val;
                                    }
                                }
                            }
                            pev.linebuf_reads += (N * N * c_in) as u64;
                        }
                        mark = lap(mark, &mut us[0]);
                        // com-PE: one live-rows-only blocked GEMM for the
                        // whole stripe, dispatched to the plan's compiled
                        // micro-kernel (scalar or SIMD, with runtime
                        // zero-skip) — filter block read once per stripe
                        pev.mults +=
                            multiply_batch(geo.kernel, rf, &scr.v, tiles_w, &mut scr.m) as u64;
                        mark = lap(mark, &mut us[1]);
                        // post-PE: inverse transform into the local stripe
                        for co in 0..l.c_out {
                            for tx in 0..tiles_w {
                                let mut m4: Tile4<E> = [[E::ZERO; N]; N];
                                for (i, row) in m4.iter_mut().enumerate() {
                                    for (j, val) in row.iter_mut().enumerate() {
                                        *val = scr.m[(co * N * N + i * N + j) * tiles_w + tx];
                                    }
                                }
                                let yt = inverse_transform(&m4);
                                for (a, row) in yt.iter().enumerate() {
                                    for (b, val) in row.iter().enumerate() {
                                        *part.at_mut(co, M * (ty - ty_s) + a, M * tx + b) = *val;
                                    }
                                }
                            }
                        }
                        lap(mark, &mut us[2]);
                    }
                    // hand-off activation on the task-local stripe (see
                    // run_layer); tile-padding rows beyond x.h are
                    // activated too but discarded by the merge below
                    let mark = trc.then(Instant::now);
                    l.act.apply(&mut part);
                    lap(mark, &mut us[3]);
                    (part, pev, us)
                },
            );
            let mut ty_base = 0;
            for (part, pev, us) in chunks {
                let rows = part.h / M;
                for co in 0..l.c_out {
                    for r in 0..part.h {
                        let oy = M * ty_base + r;
                        if oy >= x.h {
                            continue;
                        }
                        for ox in 0..x.w {
                            *y.at_mut(co, s * oy + py, s * ox + px) = part.at(co, r, ox);
                        }
                    }
                }
                ty_base += rows;
                ev.merge(&pev);
                for (acc, v) in stage_us.iter_mut().zip(us) {
                    *acc += v;
                }
            }
            // line-buffer ingest (matches run_winograd_deconv): n prologue
            // rows + m rows per stripe of the phase-padded map
            ev.linebuf_writes += ((geo.ho_t - M + N) * xp.c * xp.w) as u64;
        }
        if let Some(t0) = t_layer {
            const WINO_STAGES: [Stage; 4] = [
                Stage::InputTransform,
                Stage::WinogradGemm,
                Stage::InverseTransform,
                Stage::Activation,
            ];
            for (st, &us) in WINO_STAGES.iter().zip(&stage_us) {
                telemetry::record_span(
                    trace,
                    *st,
                    t0,
                    Duration::from_micros(us),
                    li as u64,
                    0,
                    &self.plan.model,
                );
            }
        }
        (y, ev)
    }

    /// Spatial conv datapath (DiscoGAN's encoder): strided valid
    /// correlation over the border-padded input; accumulation order matches
    /// `tdc::conv2d` bit for bit. The padded input is materialized into the
    /// run's scratch arena, like the deconv datapaths.
    fn run_conv(
        &self,
        lp: &LayerPlan<E>,
        x: &Tensor3<E>,
        n_chunks: usize,
        scratch: &mut Scratch<E>,
    ) -> (Tensor3<E>, Events) {
        let l = &lp.layer;
        let (k, s, p) = (l.k, l.s, l.p);
        // same output geometry as the tdc::conv2d reference (coincides with
        // Layer::h_out()/w_out() for every zoo encoder layer)
        let (ho, wo) = ((x.h + 2 * p - k) / s + 1, (x.w + 2 * p - k) / s + 1);
        x.pad_into(p, p, p, p, &mut scratch.xp);
        let xp = &scratch.xp;
        let g = &lp.weights;
        let chunks = self.pool.run_chunked(n_chunks, ho, |oy_s, oy_e| {
            let mut part = Tensor3::zeros(l.c_out, oy_e - oy_s, wo);
            let mut pev = Events::default();
            for co in 0..l.c_out {
                for oy in oy_s..oy_e {
                    for ox in 0..wo {
                        let mut acc = E::ZERO;
                        for ci in 0..xp.c {
                            for ky in 0..k {
                                for kx in 0..k {
                                    acc += xp.at(ci, s * oy + ky, s * ox + kx)
                                        * g.at(ci, co, ky, kx);
                                }
                            }
                        }
                        *part.at_mut(co, oy - oy_s, ox) = acc;
                    }
                }
            }
            pev.mults += (l.c_out * (oy_e - oy_s) * wo * xp.c * k * k) as u64;
            pev.stripes += (oy_e - oy_s) as u64;
            // hand-off activation on the task-local buffer (see run_layer)
            l.act.apply(&mut part);
            (part, pev)
        });
        let mut y = Tensor3::zeros(l.c_out, ho, wo);
        let mut ev = Events::default();
        let mut oy_base = 0;
        for (part, pev) in chunks {
            for co in 0..l.c_out {
                for r in 0..part.h {
                    for ox in 0..wo {
                        *y.at_mut(co, oy_base + r, ox) = part.at(co, r, ox);
                    }
                }
            }
            oy_base += part.h;
            ev.merge(&pev);
        }
        ev.linebuf_reads += ev.mults;
        ev.linebuf_writes += ((s * (ho - 1) + k).min(xp.h) * xp.c * xp.w) as u64;
        (y, ev)
    }
}

/// Advance a conditional stage clock: add the time since `mark` to
/// `acc_us` and return a fresh mark. `None` stays `None` — the untraced
/// path threads it through without ever reading the clock.
fn lap(mark: Option<Instant>, acc_us: &mut u64) -> Option<Instant> {
    mark.map(|t| {
        *acc_us += t.elapsed().as_micros() as u64;
        Instant::now()
    })
}

/// A compiled engine at a runtime-chosen [`Precision`] — the handle the
/// serving layer routes requests through. The fast ("winograd") routes of
/// a native server hold whatever tier
/// [`crate::engine::Planner::resolve_precision`] picked; the "tdc"
/// reference routes always hold the `F64` arm.
///
/// [`AnyEngine::run_packed`] is the f32 serving boundary: for the `F32`
/// arm the packed request buffer feeds the engine **without ever widening
/// to f64** — input copy, every layer, and the output repack all stay in
/// single precision (the fast path the precision tiers exist for).
#[derive(Clone, Debug)]
pub enum AnyEngine {
    F32(Engine<f32>),
    F64(Engine<f64>),
}

impl AnyEngine {
    /// Wrap a compiled f64 plan at the requested serving precision (the
    /// `F32` arm lowers it once, at build time).
    pub fn build(plan: Arc<ModelPlan<f64>>, precision: Precision, pool: Arc<WorkerPool>) -> AnyEngine {
        match precision {
            Precision::F64 => AnyEngine::F64(Engine::with_pool(plan, pool)),
            Precision::F32 => {
                AnyEngine::F32(Engine::with_pool(Arc::new(plan.lower::<f32>()), pool))
            }
        }
    }

    /// The precision tier this route executes at.
    pub fn precision(&self) -> Precision {
        match self {
            AnyEngine::F32(_) => Precision::F32,
            AnyEngine::F64(_) => Precision::F64,
        }
    }

    /// The worker pool the underlying engine dispatches to.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        match self {
            AnyEngine::F32(e) => e.pool(),
            AnyEngine::F64(e) => e.pool(),
        }
    }

    /// Worker-thread count of the underlying pool.
    pub fn workers(&self) -> usize {
        self.pool().threads()
    }

    /// `[C, H, W]` of one input sample.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        match self {
            AnyEngine::F32(e) => e.plan().input_shape,
            AnyEngine::F64(e) => e.plan().input_shape,
        }
    }

    /// Flat element count of one input sample.
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.input_shape();
        c * h * w
    }

    /// Flat element count of one output sample.
    pub fn output_len(&self) -> usize {
        match self {
            AnyEngine::F32(e) => e.plan().output_len(),
            AnyEngine::F64(e) => e.plan().output_len(),
        }
    }

    /// Execute one packed `batch x sample` f32 buffer through
    /// [`Engine::run_batch`] and repack the f32 outputs, returning the
    /// aggregate [`Events`] alongside. On the `F32` arm this is the
    /// end-to-end single-precision fast path; on the `F64` arm the buffer
    /// is widened exactly (f32 → f64 is lossless) and narrowed once on the
    /// way out, as the pre-tiered serving path always did.
    pub fn run_packed(&self, batch: usize, input: &[f32]) -> (Vec<f32>, Events) {
        match self {
            AnyEngine::F32(e) => run_packed_generic(e, batch, input),
            AnyEngine::F64(e) => run_packed_generic(e, batch, input),
        }
    }
}

fn run_packed_generic<E: Elem>(
    engine: &Engine<E>,
    batch: usize,
    input: &[f32],
) -> (Vec<f32>, Events) {
    let (c, h, w) = engine.plan().input_shape;
    let sample_in = c * h * w;
    let sample_out = engine.plan().output_len();
    assert_eq!(input.len(), batch * sample_in, "packed batch length");
    let xs: Vec<Tensor3<E>> = (0..batch)
        .map(|b| {
            let chunk = &input[b * sample_in..(b + 1) * sample_in];
            Tensor3::from_vec(c, h, w, chunk.iter().map(|&v| E::from_f32(v)).collect())
        })
        .collect();
    let runs = engine.run_batch(&xs);
    let mut out = Vec::with_capacity(batch * sample_out);
    let mut events = Events::default();
    for run in &runs {
        events.merge(&run.events);
        out.extend(run.y.data.iter().map(|&v| v.to_f32()));
    }
    (out, events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::functional::{run_tdc_deconv, run_winograd_deconv};
    use crate::engine::plan::{PlanOptions, Planner, Select};
    use crate::engine::reference_forward;
    use crate::gan::zoo::{self, Activation, Layer, Scale};
    use crate::util::prng::Rng;
    use crate::util::tensor::Filter4;

    fn rand3(rng: &mut Rng, c: usize, h: usize, w: usize) -> Tensor3 {
        Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w))
    }

    #[test]
    fn tdc_plan_bit_identical_to_reference_any_worker_count() {
        let mut rng = Rng::new(900);
        let g = zoo::dcgan(Scale::Tiny);
        let planner = Planner::new(PlanOptions {
            select: Select::Force(Method::Tdc),
            ..Default::default()
        });
        // one compiled plan shared by every engine (Arc clone, not deep clone)
        let plan = Arc::new(planner.compile_seeded(&g, 11));
        let x = rand3(&mut rng, plan.input_shape.0, plan.input_shape.1, plan.input_shape.2);
        let want = reference_forward(&plan, &x);
        for workers in [1, 2, 5] {
            let engine = Engine::with_workers(plan.clone(), workers);
            let run = engine.run(&x);
            assert_eq!(
                run.y.max_abs_diff(&want),
                0.0,
                "workers={workers}: engine must be bit-identical to the reference"
            );
        }
    }

    #[test]
    fn winograd_layer_events_match_functional_simulator() {
        // one planned layer must report exactly the events the seed's
        // per-call functional simulator measures through its line buffers
        let mut rng = Rng::new(901);
        for &(k, s, c_in, c_out, h, w) in
            &[(5usize, 2usize, 3usize, 2usize, 6usize, 8usize), (4, 2, 2, 3, 5, 7)]
        {
            let p = tdc::default_padding(k, s);
            let l = Layer {
                kind: Kind::Deconv,
                c_in,
                c_out,
                k,
                s,
                p,
                h_in: h,
                w_in: w,
                act: Activation::Linear,
            };
            let wts =
                Filter4::from_vec(c_in, c_out, k, k, rng.normal_vec(c_in * c_out * k * k));
            let planner = Planner::new(PlanOptions {
                select: Select::Force(Method::Winograd),
                ..Default::default()
            });
            let lp = planner.compile_layer(&l, wts.clone());
            let x = rand3(&mut rng, c_in, h, w);
            let engine = Engine::with_workers(
                ModelPlan {
                    model: "one-layer".into(),
                    input_shape: (c_in, h, w),
                    output_shape: (c_out, s * h, s * w),
                    layers: vec![lp],
                },
                2,
            );
            let run = engine.run(&x);
            let func = run_winograd_deconv(&x, &wts, s, p);
            assert_eq!(run.y.max_abs_diff(&func.y), 0.0, "K={k}: same dataflow, same bits");
            assert_eq!(run.events.mults, func.events.mults, "K={k}");
            assert_eq!(run.events.tiles, func.events.tiles, "K={k}");
            assert_eq!(run.events.stripes, func.events.stripes, "K={k}");
            assert_eq!(run.events.linebuf_reads, func.events.linebuf_reads, "K={k}");
            assert_eq!(run.events.linebuf_writes, func.events.linebuf_writes, "K={k}");
        }
    }

    #[test]
    fn tdc_layer_events_match_functional_simulator() {
        let mut rng = Rng::new(902);
        let (k, s, c_in, c_out, h, w) = (5usize, 2usize, 2usize, 3usize, 5usize, 7usize);
        let p = tdc::default_padding(k, s);
        let l = Layer {
            kind: Kind::Deconv,
            c_in,
            c_out,
            k,
            s,
            p,
            h_in: h,
            w_in: w,
            act: Activation::Linear,
        };
        let wts = Filter4::from_vec(c_in, c_out, k, k, rng.normal_vec(c_in * c_out * k * k));
        let planner = Planner::new(PlanOptions {
            select: Select::Force(Method::Tdc),
            ..Default::default()
        });
        let lp = planner.compile_layer(&l, wts.clone());
        let x = rand3(&mut rng, c_in, h, w);
        let engine = Engine::with_workers(
            ModelPlan {
                model: "one-layer".into(),
                input_shape: (c_in, h, w),
                output_shape: (c_out, s * h, s * w),
                layers: vec![lp],
            },
            3,
        );
        let run = engine.run(&x);
        let func = run_tdc_deconv(&x, &wts, s, p);
        assert_eq!(run.y.max_abs_diff(&func.y), 0.0);
        assert_eq!(run.events.mults, func.events.mults);
        assert_eq!(run.events.linebuf_reads, func.events.linebuf_reads);
        assert_eq!(run.events.linebuf_writes, func.events.linebuf_writes);
        assert_eq!(run.events.stripes, func.events.stripes);
    }

    #[test]
    fn auto_plan_close_to_reference_and_worker_invariant() {
        let mut rng = Rng::new(903);
        let g = zoo::gpgan(Scale::Tiny);
        let plan = Arc::new(Planner::default().compile_seeded(&g, 5));
        assert!(plan.n_winograd_layers() > 0);
        let x = rand3(&mut rng, plan.input_shape.0, plan.input_shape.1, plan.input_shape.2);
        let want = reference_forward(&plan, &x);
        let r1 = Engine::with_workers(plan.clone(), 1).run(&x);
        let r4 = Engine::with_workers(plan, 4).run(&x);
        // winograd arithmetic differs from the reference only in rounding
        let scale = want.data.iter().fold(0.0f64, |m, v| m.max(v.abs())).max(1.0);
        assert!(r1.y.max_abs_diff(&want) / scale < 1e-9);
        // ... but across worker counts the engine is bit-stable
        assert_eq!(r1.y.max_abs_diff(&r4.y), 0.0);
        assert_eq!(r1.events.mults, r4.events.mults);
    }

    #[test]
    fn batch_schedules_are_bitwise_equivalent() {
        let mut rng = Rng::new(905);
        let g = zoo::dcgan(Scale::Tiny);
        let plan = Planner::default().compile_seeded(&g, 7);
        let engine = Engine::with_workers(plan.clone(), 2);
        let xs: Vec<Tensor3> = (0..4)
            .map(|_| rand3(&mut rng, plan.input_shape.0, plan.input_shape.1, plan.input_shape.2))
            .collect();
        // wide batch on a 2-thread pool: the automatic policy goes sample-level
        assert_eq!(engine.batch_schedule(xs.len()), BatchSchedule::SampleLevel);
        assert_eq!(engine.batch_schedule(1), BatchSchedule::StripeLevel);
        let sample = engine.run_batch_with(&xs, BatchSchedule::SampleLevel);
        let stripe = engine.run_batch_with(&xs, BatchSchedule::StripeLevel);
        let auto = engine.run_batch(&xs);
        assert_eq!(sample.len(), xs.len());
        for i in 0..xs.len() {
            assert_eq!(sample[i].y.max_abs_diff(&stripe[i].y), 0.0, "sample {i}");
            assert_eq!(sample[i].y.max_abs_diff(&auto[i].y), 0.0, "sample {i}");
            assert_eq!(sample[i].events.mults, stripe[i].events.mults, "sample {i}");
            assert_eq!(sample[i].events.stripes, stripe[i].events.stripes, "sample {i}");
        }
    }

    #[test]
    fn engines_can_share_one_pool_and_one_plan() {
        let mut rng = Rng::new(906);
        let g = zoo::dcgan(Scale::Tiny);
        let plan = Arc::new(Planner::default().compile_seeded(&g, 7));
        let pool = crate::engine::pool::WorkerPool::shared(2);
        let a = Engine::with_pool(plan.clone(), pool.clone());
        let b = Engine::with_pool(a.plan_arc(), pool.clone());
        assert!(Arc::ptr_eq(a.pool(), b.pool()));
        // both engines execute the *same* compiled plan, no deep clone
        assert!(Arc::ptr_eq(&a.plan_arc(), &b.plan_arc()));
        assert_eq!(a.workers(), 2);
        let x = rand3(&mut rng, plan.input_shape.0, plan.input_shape.1, plan.input_shape.2);
        let ra = a.run(&x);
        let rb = b.run(&x);
        assert_eq!(ra.y.max_abs_diff(&rb.y), 0.0);
    }

    #[test]
    fn scratch_arenas_reused_across_runs_without_changing_bits() {
        let mut rng = Rng::new(907);
        let g = zoo::dcgan(Scale::Tiny);
        let plan = Arc::new(Planner::default().compile_seeded(&g, 7));
        let engine = Engine::with_workers(plan.clone(), 2);
        let x = rand3(&mut rng, plan.input_shape.0, plan.input_shape.1, plan.input_shape.2);
        let cold = engine.run(&x);
        // the run returned its scratches to the stash...
        assert!(engine.scratch.idle() >= 1);
        let before = engine.scratch.idle();
        // ...and warm runs reuse them without changing a single bit
        let warm = engine.run(&x);
        assert_eq!(cold.y.max_abs_diff(&warm.y), 0.0);
        assert_eq!(cold.events, warm.events);
        assert!(engine.scratch.idle() >= before, "scratches must be returned, not dropped");
        // clones share the stash and the compiled plan
        let clone = engine.clone();
        assert!(Arc::ptr_eq(&clone.scratch, &engine.scratch));
        let again = clone.run(&x);
        assert_eq!(cold.y.max_abs_diff(&again.y), 0.0);
    }

    #[test]
    fn conv_layers_run_and_chain() {
        let mut rng = Rng::new(904);
        let g = zoo::discogan(Scale::Tiny);
        let plan = Planner::default().compile_seeded(&g, 5);
        let x = rand3(&mut rng, plan.input_shape.0, plan.input_shape.1, plan.input_shape.2);
        let run = Engine::with_workers(plan.clone(), 2).run(&x);
        assert_eq!((run.y.c, run.y.h, run.y.w), plan.output_shape);
        assert_eq!(run.per_layer.len(), g.layers.len());
        assert!(run.per_layer.iter().all(|e| e.mults > 0));
    }

    #[test]
    fn engine_applies_layer_activations() {
        // a single-layer plan with each activation: the engine output must
        // equal the Linear output passed through the activation elementwise
        // (and match reference_forward, which applies the same function)
        let mut rng = Rng::new(908);
        let base = Layer::deconv(2, 2, 5, 2, 4);
        let wts = Filter4::from_vec(2, 2, 5, 5, rng.normal_vec(2 * 2 * 25));
        let x = rand3(&mut rng, 2, 4, 4);
        let planner = Planner::new(PlanOptions {
            select: Select::Force(Method::Tdc),
            ..Default::default()
        });
        let make_plan = |act: Activation| {
            let l = base.with_act(act);
            Arc::new(ModelPlan {
                model: "act-test".into(),
                input_shape: (2, 4, 4),
                output_shape: (2, 8, 8),
                layers: vec![planner.compile_layer(&l, wts.clone())],
            })
        };
        let linear = Engine::with_workers(make_plan(Activation::Linear), 2).run(&x);
        for act in [Activation::Relu, Activation::LeakyRelu, Activation::Tanh] {
            let plan = make_plan(act);
            let run = Engine::with_workers(plan.clone(), 2).run(&x);
            let mut want = linear.y.clone();
            act.apply(&mut want);
            assert_eq!(run.y.max_abs_diff(&want), 0.0, "{act:?}");
            let reference = reference_forward(&plan, &x);
            assert_eq!(run.y.max_abs_diff(&reference), 0.0, "{act:?} vs reference");
            // activations never change the event accounting
            assert_eq!(run.events, linear.events, "{act:?}");
        }
        // the relu plan actually clamps something (generic weights produce
        // both signs) and tanh bounds the output
        let relu = Engine::with_workers(make_plan(Activation::Relu), 1).run(&x);
        assert!(relu.y.data.iter().all(|v| *v >= 0.0));
        let tanh = Engine::with_workers(make_plan(Activation::Tanh), 1).run(&x);
        assert!(tanh.y.data.iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn f32_engine_runs_the_same_plan_structure() {
        // the f32 tier executes the lowered plan with identical events and
        // agrees with the f64 tier to single-precision rounding
        let mut rng = Rng::new(909);
        let g = zoo::dcgan(Scale::Tiny);
        let plan64 = Arc::new(Planner::default().compile_seeded(&g, 7));
        let plan32 = Arc::new(plan64.lower::<f32>());
        let x64 = rand3(&mut rng, plan64.input_shape.0, plan64.input_shape.1, plan64.input_shape.2);
        let x32: Tensor3<f32> = x64.cast_to();
        let r64 = Engine::with_workers(plan64.clone(), 2).run(&x64);
        let e32 = Engine::with_workers(plan32.clone(), 2);
        assert_eq!(e32.precision(), Precision::F32);
        assert_eq!(e32.plan().precision(), Precision::F32);
        let r32 = e32.run(&x32);
        assert_eq!(r32.events, r64.events, "event accounting is precision-independent");
        let scale = r64.y.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        let rel = r32.y.cast_to::<f64>().max_abs_diff(&r64.y) / scale;
        assert!(rel < 1e-3, "f32 tier must track the f64 reference (rel {rel})");
    }

    #[test]
    fn any_engine_routes_by_precision() {
        let mut rng = Rng::new(910);
        let g = zoo::dcgan(Scale::Tiny);
        let plan = Arc::new(Planner::default().compile_seeded(&g, 7));
        let pool = WorkerPool::shared(2);
        let a32 = AnyEngine::build(plan.clone(), Precision::F32, pool.clone());
        let a64 = AnyEngine::build(plan.clone(), Precision::F64, pool.clone());
        assert_eq!(a32.precision(), Precision::F32);
        assert_eq!(a64.precision(), Precision::F64);
        assert_eq!(a32.input_len(), plan.input_len());
        assert_eq!(a64.output_len(), plan.output_len());
        assert!(Arc::ptr_eq(a32.pool(), a64.pool()));
        let input = rng.normal_vec_f32(2 * plan.input_len());
        let (y32, ev32) = a32.run_packed(2, &input);
        let (y64, ev64) = a64.run_packed(2, &input);
        assert_eq!(y32.len(), 2 * plan.output_len());
        assert_eq!(y64.len(), y32.len());
        assert_eq!(ev32, ev64, "events are precision-independent");
        let diff = crate::util::bin::max_abs_diff(&y32, &y64);
        assert!(diff < 1e-3, "tiers agree to f32 rounding: {diff}");
        // determinism per tier
        let (y32b, _) = a32.run_packed(2, &input);
        assert_eq!(y32, y32b);
    }
}
