//! # wingan — Winograd DeConv acceleration for GANs
//!
//! Production-grade reproduction of *"Towards Design Methodology of
//! Efficient Fast Algorithms for Accelerating Generative Adversarial
//! Networks on FPGAs"* (Chang, Ahn, Kang & Kang, 2019).
//!
//! Three-layer architecture:
//! * **L1/L2 (build time)** — python/compile: Pallas Winograd-DeConv kernel
//!   + JAX generators, AOT-lowered to HLO text artifacts.
//! * **L3 (this crate)** — compiles `gan::zoo` models into precompiled
//!   per-layer plans and executes whole generators natively ([`engine`]),
//!   serves generation requests through batched routes ([`coordinator`]),
//!   optionally loads the AOT artifacts via PJRT ([`runtime`]; gated off in
//!   offline builds), and reproduces the paper's entire evaluation on a
//!   cycle-level FPGA accelerator simulator ([`accel`], [`dse`],
//!   [`resource`], [`energy`]).
//!
//! The **plan-compile / execute split** is the load-bearing design: a
//! [`engine::Planner`] does all per-model derivation once (TDC phase
//! decomposition, Winograd `G g Gᵀ` filter transforms + sparsity
//! reordering, DSE-raced method selection, line-buffer geometry), and the
//! [`engine::Engine`] then runs the whole generator per request with
//! stripe/tile parallelism — bit-identical (f64) to the layer-composed
//! `tdc` standard-DeConv reference on the exact datapath, and
//! worker-count-invariant everywhere.
//!
//! The algorithmic substrates ([`tdc`], [`winograd`], [`gan`]) mirror the
//! python oracles; `rust/tests/proptests.rs` pins them to each other and
//! pins the engine to the composed reference.


pub mod accel;
pub mod benchlib;
pub mod cli;
pub mod coordinator;
pub mod dse;
pub mod energy;
pub mod engine;
pub mod gan;
pub mod prop;
pub mod report;
pub mod resource;
pub mod runtime;
pub mod tdc;
pub mod util;
pub mod winograd;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
