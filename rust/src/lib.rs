//! # wingan — Winograd DeConv acceleration for GANs
//!
//! Production-grade reproduction of *"Towards Design Methodology of
//! Efficient Fast Algorithms for Accelerating Generative Adversarial
//! Networks on FPGAs"* (Chang, Ahn, Kang & Kang, 2019).
//!
//! Three-layer architecture:
//! * **L1/L2 (build time)** — python/compile: Pallas Winograd-DeConv kernel
//!   + JAX generators, AOT-lowered to HLO text artifacts.
//! * **L3 (this crate)** — loads the artifacts via PJRT ([`runtime`]),
//!   serves generation requests ([`coordinator`]), and reproduces the
//!   paper's entire evaluation on a cycle-level FPGA accelerator simulator
//!   ([`accel`], [`dse`], [`resource`], [`energy`]).
//!
//! The algorithmic substrates ([`tdc`], [`winograd`], [`gan`]) mirror the
//! python oracles; `rust/tests/proptests.rs` pins them to each other.


pub mod accel;
pub mod benchlib;
pub mod cli;
pub mod coordinator;
pub mod dse;
pub mod energy;
pub mod gan;
pub mod prop;
pub mod report;
pub mod resource;
pub mod runtime;
pub mod tdc;
pub mod util;
pub mod winograd;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
