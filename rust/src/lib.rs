//! # wingan — Winograd DeConv acceleration for GANs
//!
//! Production-grade reproduction of *"Towards Design Methodology of
//! Efficient Fast Algorithms for Accelerating Generative Adversarial
//! Networks on FPGAs"* (Chang, Ahn, Kang & Kang, 2019).
//!
//! Three-layer architecture:
//! * **L1/L2 (build time)** — python/compile: Pallas Winograd-DeConv kernel
//!   + JAX generators, AOT-lowered to HLO text artifacts.
//! * **L3 (this crate)** — compiles `gan::zoo` models into precompiled
//!   per-layer plans and executes whole generators natively ([`engine`]),
//!   serves generation requests through batched routes ([`coordinator`]),
//!   optionally loads the AOT artifacts via PJRT ([`runtime`]; gated off in
//!   offline builds), and reproduces the paper's entire evaluation on a
//!   cycle-level FPGA accelerator simulator ([`accel`], [`dse`],
//!   [`resource`], [`energy`]).
//!
//! # Module map
//!
//! | Module | Role (paper anchor) |
//! |---|---|
//! | [`tdc`] | DeConv-to-Conv conversion + reference DeConv (§II.A, §III-A) |
//! | [`winograd`] | F(2×2, 3×3) transforms, Table-I sparsity, reordered layout (§II.B, §III.B) |
//! | [`gan`] | Table-I model zoo + workload characterisation |
//! | [`engine`] | plan compile → two-level parallel execute → native serving (§IV dataflow) |
//! | [`artifact`] | versioned plan serialization + on-disk store (AOT compile → warm serve) |
//! | [`coordinator`] | router, dynamic batcher, serving engine thread, metrics |
//! | [`runtime`] | PJRT artifact manifest + (offline-gated) executor |
//! | [`accel`] | line buffers, functional dataflow, cycle model (§IV.B, §V) |
//! | [`dse`] | design-space exploration, eqs. 5–9 (§IV.C) |
//! | [`resource`] / [`energy`] | Table II resource + Fig. 9 energy models |
//! | [`report`] | the paper's tables/figures as printable reports |
//! | [`loadgen`] | open-loop Poisson load harness: scheduler A/B under mixed traffic |
//! | [`faultinject`] | seeded deterministic fault-injection plane (panic/delay/corrupt sites) |
//! | [`chaos`] | fault-injection soak: conservation, bitwise isolation, bounded recovery |
//! | [`fleet`] | multi-process serving: wire protocol, replicas, failover router, rolling republish |
//! | [`telemetry`] | end-to-end request tracing, flight recorder, scrapeable JSON/Prometheus exports |
//! | [`cli`] / [`benchlib`] / [`util`] / [`prop`] | flag parsing, bench harness, tensors/PRNG/JSON, property-test harness |
//!
//! The **plan-compile / execute split** is the load-bearing design: a
//! [`engine::Planner`] does all per-model derivation once (TDC phase
//! decomposition, Winograd `G g Gᵀ` filter transforms + sparsity
//! reordering, DSE-raced method selection, line-buffer geometry), and the
//! [`engine::Engine`] then runs the whole generator per request on a
//! persistent [`engine::WorkerPool`] with two-level (sample × stripe)
//! scheduling — bit-identical (f64) to the layer-composed `tdc`
//! standard-DeConv reference on the exact datapath, and invariant, bit for
//! bit, to worker count and batch schedule everywhere.
//!
//! Compiled plans are also **deployment artifacts** ([`artifact`]): a
//! versioned, checksummed binary codec round-trips every plan bit-exactly,
//! and an on-disk [`artifact::PlanStore`] turns serving cold-start into a
//! file read — `wingan compile` ahead of time, `wingan serve --plan-store`
//! boots without invoking the planner (falling back to in-process
//! compilation, then publishing, when artifacts are missing).
//!
//! The execution datapath is **precision-tiered** ([`util::elem::Elem`],
//! [`engine::Precision`]): every kernel is generic over the scalar
//! element, `f64` is the reference tier the contracts are stated at, and
//! the `f32` tier is the serving fast path (half the memory traffic on the
//! reordered filter slabs and gathered tile matrices, double the SIMD
//! width) with a tolerance contract against the f64 reference and the
//! same bitwise scheduling invariance.
//!
//! The algorithmic substrates ([`tdc`], [`winograd`], [`gan`]) mirror the
//! python oracles; `rust/tests/proptests.rs` pins them to each other and
//! pins the engine to the composed reference.

// Lint policy: CI gates `cargo clippy --all-targets -- -D warnings` with
// exactly these two style lints allowed crate-wide — the numeric kernels
// are written index-style on purpose (i/j/tap loops mirror the paper's
// matrix algebra), and a few serving signatures spell out nested
// channel/result types deliberately.
#![allow(clippy::needless_range_loop, clippy::type_complexity)]

pub mod accel;
pub mod artifact;
pub mod benchlib;
pub mod chaos;
pub mod cli;
pub mod coordinator;
pub mod dse;
pub mod energy;
pub mod engine;
pub mod faultinject;
pub mod fleet;
pub mod gan;
pub mod loadgen;
pub mod prop;
pub mod report;
pub mod resource;
pub mod runtime;
pub mod tdc;
pub mod telemetry;
pub mod util;
pub mod winograd;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
