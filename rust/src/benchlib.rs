//! Minimal benchmark harness (criterion is unavailable offline): warmup,
//! calibrated iteration count, mean/stddev/min over samples, and a stable
//! one-line report format the bench binaries share.
//!
//! Not a statistical match for criterion, but honest: wall-clock medians
//! over multiple samples with an explicit black_box to defeat DCE.

use std::collections::BTreeMap;
use std::hint::black_box as bb;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

/// Re-export for bench bodies.
pub use std::hint::black_box;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
    pub iters_per_sample: u32,
}

impl Measurement {
    fn per_iter_secs(&self) -> Vec<f64> {
        self.samples
            .iter()
            .map(|d| d.as_secs_f64() / self.iters_per_sample as f64)
            .collect()
    }

    pub fn mean(&self) -> f64 {
        let v = self.per_iter_secs();
        v.iter().sum::<f64>() / v.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let v = self.per_iter_secs();
        let m = self.mean();
        (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.per_iter_secs().into_iter().fold(f64::INFINITY, f64::min)
    }

    pub fn median(&self) -> f64 {
        let mut v = self.per_iter_secs();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }

    /// Items per second when one iteration processes `items` units
    /// (samples, requests, tiles) — median-based, like `report`.
    pub fn throughput(&self, items: usize) -> f64 {
        items as f64 / self.median()
    }

    pub fn report(&self) -> String {
        let scale = |s: f64| {
            if s < 1e-6 {
                format!("{:8.1} ns", s * 1e9)
            } else if s < 1e-3 {
                format!("{:8.2} µs", s * 1e6)
            } else if s < 1.0 {
                format!("{:8.3} ms", s * 1e3)
            } else {
                format!("{s:8.3} s ")
            }
        };
        format!(
            "{:<44} median {}  mean {}  ±{:<9}  min {}",
            self.name,
            scale(self.median()),
            scale(self.mean()),
            scale(self.stddev()).trim_start(),
            scale(self.min()),
        )
    }
}

/// Median speedup of `new` over `base` (`> 1.0` = `new` is faster). Used
/// by the hot-path benches to print spawn-overhead-elimination and
/// batch-scaling factors on one stable format.
pub fn speedup(base: &Measurement, new: &Measurement) -> f64 {
    base.median() / new.median()
}

/// One-line comparison report: `label: 3.1x (base 1.2 ms -> new 0.4 ms)`.
pub fn speedup_line(label: &str, base: &Measurement, new: &Measurement) -> String {
    format!(
        "  -> {label}: {:.2}x ({:.3} ms -> {:.3} ms, medians)",
        speedup(base, new),
        base.median() * 1e3,
        new.median() * 1e3,
    )
}

/// Machine-readable benchmark emitter: collects [`Measurement`]s and named
/// scalar metrics (speedups, throughputs) and serializes them as one JSON
/// document — the `BENCH_*.json` perf-trajectory files the ROADMAP's
/// north-star tracks, uploaded as a CI artifact by the bench smoke step.
///
/// Times are recorded in integer nanoseconds per iteration (median, mean,
/// min over samples), matching what [`Measurement::report`] prints.
#[derive(Debug, Default)]
pub struct BenchReport {
    bench: String,
    measurements: BTreeMap<String, Json>,
    metrics: BTreeMap<String, f64>,
}

impl BenchReport {
    /// A report for the named bench binary (e.g. `"hotpath"`).
    pub fn new(bench: &str) -> BenchReport {
        BenchReport { bench: bench.to_string(), ..Default::default() }
    }

    /// Record one measurement under its name.
    pub fn record(&mut self, m: &Measurement) {
        self.record_as(&m.name, m);
    }

    /// Record a measurement under a stable key independent of its printed
    /// name — use when the display name embeds machine-dependent details
    /// (worker counts, core counts) that would make trajectory files
    /// incomparable across runners.
    pub fn record_as(&mut self, key: &str, m: &Measurement) {
        let fields: BTreeMap<String, Json> = [
            ("ns_per_iter".to_string(), Json::Num((m.median() * 1e9).round())),
            ("mean_ns".to_string(), Json::Num((m.mean() * 1e9).round())),
            ("min_ns".to_string(), Json::Num((m.min() * 1e9).round())),
            ("samples".to_string(), Json::Num(m.samples.len() as f64)),
        ]
        .into_iter()
        .collect();
        self.measurements.insert(key.to_string(), Json::Obj(fields));
    }

    /// Record a named scalar metric (a speedup factor, tiles/sec, ...).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.insert(name.to_string(), value);
    }

    /// The report as a JSON document.
    pub fn to_json(&self) -> Json {
        let metrics: BTreeMap<String, Json> =
            self.metrics.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
        Json::Obj(
            [
                ("bench".to_string(), Json::Str(self.bench.clone())),
                ("measurements".to_string(), Json::Obj(self.measurements.clone())),
                ("metrics".to_string(), Json::Obj(metrics)),
            ]
            .into_iter()
            .collect(),
        )
    }

    /// Write the pretty-printed JSON document to `path`.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, json::to_string_pretty(&self.to_json()))
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: Duration::from_millis(100), budget: Duration::from_millis(800), samples: 10 }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup: Duration::from_millis(20), budget: Duration::from_millis(200), samples: 5 }
    }

    /// Run `f` repeatedly; prints and returns the measurement.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Measurement {
        // warmup + calibration
        let t0 = Instant::now();
        let mut warm_iters = 0u32;
        while t0.elapsed() < self.warmup {
            bb(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let per_sample = self.budget.as_secs_f64() / self.samples as f64;
        let iters = ((per_sample / per_iter).ceil() as u32).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            samples.push(t.elapsed());
        }
        let m = Measurement { name: name.to_string(), samples, iters_per_sample: iters };
        println!("{}", m.report());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench { warmup: Duration::from_millis(5), budget: Duration::from_millis(20), samples: 3 };
        let m = b.run("noop-ish", || (0..100).sum::<u64>());
        assert!(m.mean() > 0.0);
        assert!(m.min() <= m.mean());
        assert_eq!(m.samples.len(), 3);
    }

    #[test]
    fn speedup_and_throughput() {
        let base = Measurement {
            name: "base".into(),
            samples: vec![Duration::from_millis(10); 3],
            iters_per_sample: 1,
        };
        let fast = Measurement {
            name: "fast".into(),
            samples: vec![Duration::from_millis(2); 3],
            iters_per_sample: 1,
        };
        assert!((speedup(&base, &fast) - 5.0).abs() < 1e-9);
        assert!((fast.throughput(8) - 4000.0).abs() < 1e-6);
        let line = speedup_line("batch scaling", &base, &fast);
        assert!(line.contains("5.00x"), "{line}");
    }

    #[test]
    fn bench_report_emits_parseable_json() {
        let m = Measurement {
            name: "winograd: batched stripe".into(),
            samples: vec![Duration::from_micros(250); 4],
            iters_per_sample: 10,
        };
        let mut rep = BenchReport::new("hotpath");
        rep.record(&m);
        rep.metric("winograd_batched_speedup_1w", 1.75);
        let doc = json::to_string_pretty(&rep.to_json());
        let back = json::parse(&doc).expect("report must serialize to valid JSON");
        assert_eq!(back.get("bench").and_then(Json::as_str), Some("hotpath"));
        let ns = back
            .get("measurements")
            .and_then(|ms| ms.get("winograd: batched stripe"))
            .and_then(|m| m.get("ns_per_iter"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!((ns - 25_000.0).abs() < 1.0, "ns_per_iter = {ns}");
        let sp = back
            .get("metrics")
            .and_then(|m| m.get("winograd_batched_speedup_1w"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!((sp - 1.75).abs() < 1e-12);
    }

    #[test]
    fn report_formats() {
        let m = Measurement {
            name: "x".into(),
            samples: vec![Duration::from_micros(100); 4],
            iters_per_sample: 100,
        };
        let r = m.report();
        assert!(r.contains("µs") || r.contains("ns"), "{r}");
    }
}
