//! Bench: reproduce **Fig. 4** — total number of reduced multiplications in
//! the DeConv layers of each GAN under zero-padded / TDC / Winograd — and
//! time the analytic workload model plus the *measured* counterpart (the
//! functional simulator's issued-multiplication counter on a scaled layer).

use wingan::accel::functional::run_winograd_deconv;
use wingan::benchlib::{black_box, Bench};
use wingan::gan::workload::{fig4_row, layer_mults, Method};
use wingan::gan::zoo::{self, Scale};
use wingan::report;
use wingan::tdc::default_padding;
use wingan::util::prng::Rng;
use wingan::util::tensor::{Filter4, Tensor3};

fn main() {
    println!("==========================================================");
    println!(" Fig. 4 reproduction — DeConv multiplication counts");
    println!("==========================================================");
    print!("{}", report::fig4());

    // sparsity-case preamble (Fig. 3/6 evidence)
    println!("\nWinograd-domain sparsity cases per kernel class:");
    for (k, s) in [(5usize, 2usize), (4, 2), (3, 1)] {
        let p = default_padding(k, s);
        let cases = wingan::winograd::phase_cases(k, s, p);
        let live: Vec<usize> = cases.iter().map(|c| c.live_positions()).collect();
        println!(
            "  K_D={k} S={s}: cases {:?} -> live positions {live:?} (C = {})",
            cases.iter().map(|c| c.number()).collect::<Vec<_>>(),
            wingan::winograd::c_of_kc(k, s, p)
        );
    }

    // cross-check: analytic count == functional simulator's issued mults
    println!("\nanalytic-vs-measured cross-check (small layer, K=5 S=2):");
    let mut rng = Rng::new(99);
    let (c_in, c_out, h, w) = (4usize, 3usize, 8usize, 8usize);
    let x = Tensor3::from_vec(c_in, h, w, rng.normal_vec(c_in * h * w));
    let wt = Filter4::from_vec(c_in, c_out, 5, 5, rng.normal_vec(c_in * c_out * 25));
    let run = run_winograd_deconv(&x, &wt, 2, 2);
    let l = wingan::gan::zoo::Layer {
        kind: wingan::gan::zoo::Kind::Deconv,
        c_in,
        c_out,
        k: 5,
        s: 2,
        p: 2,
        h_in: h,
        w_in: w,
        act: wingan::gan::zoo::Activation::Linear,
    };
    let analytic = layer_mults(&l, Method::Winograd);
    println!(
        "  measured {} vs analytic {analytic} -> {}",
        run.events.mults,
        if run.events.mults == analytic { "MATCH" } else { "MISMATCH" }
    );
    assert_eq!(run.events.mults, analytic);

    // ablation: why uniform F(2x2,3x3)? F(4x4,3x3) mults vs numerics
    println!("\nablation — tile size F(2,3) vs F(4,3) (mults/output; f32 max err on a 6x6 patch):");
    for (k, s) in [(5usize, 2usize), (4, 2), (3, 1)] {
        let p = default_padding(k, s);
        let (td, f23, f43) = wingan::winograd::f43::mults_per_output(k, s, p);
        println!(
            "  K_D={k} S={s}: TDC {td:.2}  F(2,3) {f23:.2}  F(4,3) {f43:.2}  (further {:.2}x)",
            f23 / f43
        );
    }
    let (mut e23_max, mut e43_max) = (0f64, 0f64);
    for seed in 0..8 {
        let (e23, e43) = wingan::winograd::f43::f32_error_comparison(seed);
        e23_max = e23_max.max(e23);
        e43_max = e43_max.max(e43);
    }
    println!(
        "  f32 error (max over 8 seeds): F(2,3) {e23_max:.2e} vs F(4,3) {e43_max:.2e} \
         ({:.1}x worse) -> with the fabric-multiplier cost of the 1/24-scale\n  transforms, \
         F(2,3) is the right design point; the paper's choice is justified",
        e43_max / e23_max
    );

    println!("\n-- timings --");
    let b = Bench::default();
    b.run("fig4: analytic counts, all 4 GANs", || {
        let mut acc = 0u64;
        for g in zoo::all(Scale::Paper) {
            let (a, t, c) = fig4_row(&g);
            acc = acc.wrapping_add(a).wrapping_add(t).wrapping_add(c);
        }
        black_box(acc)
    });
    b.run("fig4: functional sim, one K=5 layer (4x3x8x8)", || {
        black_box(run_winograd_deconv(&x, &wt, 2, 2).events.mults)
    });
}
