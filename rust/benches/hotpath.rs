//! Bench: hot-path microbenchmarks for the §Perf optimisation pass —
//! Winograd transforms, the reordered com-PE engine, the functional/cycle
//! simulators, the persistent worker pool (spawn-overhead elimination +
//! batch-level scaling), the batcher, JSON, and (if artifacts exist) the
//! PJRT execute path that serves requests.

use std::sync::Arc;
use std::time::{Duration, Instant};
use wingan::accel::functional::{phase_padded, run_winograd_deconv};
use wingan::accel::{simulate_model, AccelConfig};
use wingan::artifact::{AnyPlan, PlanKey, PlanStore};
use wingan::engine::Precision;
use wingan::benchlib::{black_box, speedup, speedup_line, Bench, BenchReport};
use wingan::engine::pool::WorkerPool;
use wingan::engine::BatchSchedule;
use wingan::coordinator::batcher::{BatchPolicy, ContinuousBatcher, DynamicBatcher};
use wingan::coordinator::request::GenRequest;
use wingan::engine::plan::seeded_weights;
use wingan::engine::{Engine, ModelPlan, PlanOptions, Planner, Select};
use wingan::gan::workload::Method;
use wingan::gan::zoo::{self, Scale};
use wingan::tdc;
use wingan::util::prng::Rng;
use wingan::util::tensor::{Filter4, Tensor3};
use wingan::winograd::kernel::{multiply_batch, simd_available, KernelKind, RunList};
use wingan::winograd::layout::{engine_multiply, reorder_filter, reorder_input_tile};
use wingan::winograd::transforms::{filter_transform, input_transform, inverse_transform, M};

/// The pre-PR3 per-tile Winograd datapath, replayed over the same
/// precompiled plans: one GEMV + fresh `ReorderedTile`/accumulator buffers
/// per tile, one fresh phase-padded tensor per phase, single-threaded.
/// This is the baseline the stripe-batched GEMM engine is measured against
/// (and asserted bit-identical to). Returns the output and the tile count.
fn per_tile_winograd_forward(plan: &ModelPlan, x: &Tensor3) -> (Tensor3, u64) {
    let mut tiles = 0u64;
    let mut cur = x.clone();
    for lp in &plan.layers {
        let l = &lp.layer;
        assert_eq!(lp.method, Method::Winograd, "baseline expects winograd plans");
        let s = l.s;
        let mut y = Tensor3::zeros(l.c_out, s * cur.h, s * cur.w);
        let ho_t = cur.h.div_ceil(M) * M;
        let wo_t = cur.w.div_ceil(M) * M;
        for (idx, rf) in lp.reordered.iter().enumerate() {
            let ph = &lp.phases[idx];
            let (py, px) = (idx / s, idx % s);
            let xp = phase_padded(&cur, ph, ho_t, wo_t);
            for ty in 0..ho_t / M {
                for tx in 0..wo_t / M {
                    tiles += 1;
                    let vt = reorder_input_tile(&xp, ty, tx);
                    let (m_acc, _) = engine_multiply(rf, &vt);
                    for co in 0..l.c_out {
                        let yt = inverse_transform(&m_acc[co]);
                        for (a, row) in yt.iter().enumerate() {
                            let oy = M * ty + a;
                            if oy >= cur.h {
                                continue;
                            }
                            for (b, val) in row.iter().enumerate() {
                                let ox = M * tx + b;
                                if ox >= cur.w {
                                    continue;
                                }
                                *y.at_mut(co, s * oy + py, s * ox + px) = *val;
                            }
                        }
                    }
                }
            }
        }
        // same hand-off activation the engine applies (zoo layers carry
        // relu/tanh since PR 4)
        l.act.apply(&mut y);
        cur = y;
    }
    (cur, tiles)
}

fn main() {
    println!("==========================================================");
    println!(" hot-path microbenchmarks (see EXPERIMENTS.md §Perf)");
    println!("==========================================================");
    // --quick: CI smoke mode — short budgets, same structure + JSON output
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bench::quick() } else { Bench::default() };
    let mut report = BenchReport::new("hotpath");
    let mut rng = Rng::new(7);

    // --- L3 substrate kernels -------------------------------------------
    let mut z = [[0.0; 4]; 4];
    for row in z.iter_mut() {
        for v in row.iter_mut() {
            *v = rng.normal();
        }
    }
    let f = {
        let mut f = [[0.0; 3]; 3];
        for row in f.iter_mut() {
            for v in row.iter_mut() {
                *v = rng.normal();
            }
        }
        f
    };
    b.run("winograd: input transform B^T Z B (4x4)", || black_box(input_transform(&z)));
    b.run("winograd: filter transform G f G^T (3x3)", || black_box(filter_transform(&f)));
    b.run("winograd: inverse transform A^T M A", || black_box(inverse_transform(&z)));

    // reordered engine: one tile, 64 channels in, 4 out (a T_m group)
    let (c_in, c_out) = (64usize, 4usize);
    let w4 = Filter4::from_vec(c_in, c_out, 4, 4, rng.normal_vec(c_in * c_out * 16));
    let phases = tdc::decompose(&w4, 2, 1);
    let rf = reorder_filter(&phases[0]);
    let xt = Tensor3::from_vec(c_in, 4, 4, rng.normal_vec(c_in * 16));
    let vt = reorder_input_tile(&xt, 0, 0);
    b.run("engine: pre-PE reorder tile (64ch)", || black_box(reorder_input_tile(&xt, 0, 0)));
    b.run("engine: com-PE sparse multiply (64x4, case3)", || {
        black_box(engine_multiply(&rf, &vt).1)
    });

    // functional simulator, one realistic small layer
    let x = Tensor3::from_vec(16, 16, 16, rng.normal_vec(16 * 16 * 16));
    let w5 = Filter4::from_vec(16, 8, 5, 5, rng.normal_vec(16 * 8 * 25));
    b.run("functional sim: 16x8 deconv K5S2 on 16x16", || {
        black_box(run_winograd_deconv(&x, &w5, 2, 2).events.mults)
    });

    // --- engine: precompiled plans + parallel tiles vs the seed path -----
    // the seed served layers through run_winograd_deconv, which re-derives
    // phase filters + G g G^T transforms + reordered layouts on EVERY call;
    // the engine compiles a whole-model plan once and only executes.
    let g_small = zoo::dcgan(Scale::Small);
    let planner = Planner::default();
    let plan = planner.compile_seeded(&g_small, 7);
    let weights = seeded_weights(&g_small, 7);
    let (ci0, h0, w0) = plan.input_shape;
    let x0 = Tensor3::from_vec(ci0, h0, w0, rng.normal_vec(ci0 * h0 * w0));
    b.run("engine: plan compile DCGAN-small (once per model)", || {
        black_box(planner.compile_seeded(&g_small, 7).layers.len())
    });
    let e1 = Engine::with_workers(plan.clone(), 1);
    let en = Engine::new(plan.clone());
    let m_seed = b.run("seed path: DCGAN-small, per-call functional (re-derives)", || {
        let mut cur = x0.clone();
        for (l, w) in g_small.layers.iter().zip(&weights) {
            cur = run_winograd_deconv(&cur, w, l.s, l.p).y;
        }
        black_box(cur.data.len())
    });
    let m_e1 = b.run("engine: DCGAN-small, precompiled plan, 1 worker", || {
        black_box(e1.run(&x0).y.data.len())
    });
    let m_en = b.run(
        &format!("engine: DCGAN-small, precompiled plan, {} workers", en.workers()),
        || black_box(en.run(&x0).y.data.len()),
    );
    println!(
        "  -> plan-cache win: {:.2}x (1 worker vs seed per-call)   parallel win: {:.2}x \
         ({} workers vs seed per-call)",
        m_seed.median() / m_e1.median(),
        m_seed.median() / m_en.median(),
        en.workers()
    );
    report.metric("plan_cache_speedup_1w", speedup(&m_seed, &m_e1));

    // --- winograd datapath: tile-batched GEMM vs the per-tile path -------
    // PR 3 restructured the Winograd execution from per-tile GEMV into
    // stripe-level batched GEMM backed by per-worker scratch arenas: the
    // reordered filter slab is streamed once per stripe instead of once per
    // tile, and the hot loop allocates nothing per tile. The baseline
    // replays the old per-tile loop over the same precompiled plans.
    // Paper-scale DCGAN (Table I widths): the reordered slabs are MBs per
    // phase, so per-tile re-streaming is what actually dominates — the
    // blocking win the DeConv/Winograd DSE literature predicts.
    let wplanner = Planner::new(PlanOptions {
        select: Select::Force(Method::Winograd),
        ..Default::default()
    });
    let wplan = Arc::new(wplanner.compile_seeded(&zoo::dcgan(Scale::Paper), 7));
    let (wc, wh, ww) = wplan.input_shape;
    let wx = Tensor3::from_vec(wc, wh, ww, rng.normal_vec(wc * wh * ww));
    let we1 = Engine::with_workers(wplan.clone(), 1);
    let wen = Engine::new(wplan.clone());
    let (y_base, tiles_per_run) = per_tile_winograd_forward(&wplan, &wx);
    // the refactor's numerics contract, checked on every bench run
    assert_eq!(
        y_base.max_abs_diff(&we1.run(&wx).y),
        0.0,
        "stripe-batched datapath must be bit-identical to the per-tile path"
    );
    // paper-scale forwards run for hundreds of ms each: --quick keeps CI
    // fast, full runs widen the budget so the headline trajectory metrics
    // aren't single-iteration noise
    let wb = if quick {
        Bench::quick()
    } else {
        Bench { warmup: Duration::from_millis(200), budget: Duration::from_secs(4), samples: 8 }
    };
    let m_tile = wb.run("winograd: DCGAN-paper, per-tile GEMV (PR-2 path)", || {
        black_box(per_tile_winograd_forward(&wplan, &wx).0.data.len())
    });
    let m_batch1 = wb.run("winograd: DCGAN-paper, stripe-batched GEMM, 1 worker", || {
        black_box(we1.run(&wx).y.data.len())
    });
    let m_batchn = wb.run(
        &format!("winograd: DCGAN-paper, stripe-batched GEMM, {} workers", wen.workers()),
        || black_box(wen.run(&wx).y.data.len()),
    );
    println!("{}", speedup_line("tile-batched GEMM vs per-tile (1 worker)", &m_tile, &m_batch1));
    println!("{}", speedup_line("tile-batched GEMM + workers vs per-tile", &m_tile, &m_batchn));
    println!(
        "  -> winograd throughput: {:.0} tiles/s (1 worker), {:.0} tiles/s ({} workers); \
         {tiles_per_run} tiles/run",
        m_batch1.throughput(tiles_per_run as usize),
        m_batchn.throughput(tiles_per_run as usize),
        wen.workers(),
    );
    report.record(&m_tile);
    report.record(&m_batch1);
    // stable key: the display name embeds the machine's worker count
    report.record_as("winograd: DCGAN-paper, stripe-batched GEMM, parallel", &m_batchn);
    report.metric("winograd_batched_speedup_1w", speedup(&m_tile, &m_batch1));
    report.metric("winograd_batched_speedup_parallel", speedup(&m_tile, &m_batchn));
    report.metric("winograd_tiles_per_sec_1w", m_batch1.throughput(tiles_per_run as usize));
    report.metric("winograd_tiles_per_sec_parallel", m_batchn.throughput(tiles_per_run as usize));
    report.metric("winograd_tiles_per_run", tiles_per_run as f64);
    report.metric("workers", wen.workers() as f64);

    // --- precision tiers: f32 serving fast path vs the f64 reference -----
    // PR 4 made the whole datapath generic over the scalar element and
    // lowered serving plans to a precision tier: the f32 tier halves the
    // bytes behind the reordered filter slabs (the stream that dominates
    // at paper scale — MBs per phase) and the gathered tile matrices, and
    // doubles the SIMD width of the blocked GEMM micro-kernel. This is the
    // acceptance head-to-head: same model, same plan structure, same
    // blocked kernel, f32 vs f64.
    let wplan32 = Arc::new(wplan.lower::<f32>());
    let wx32: Tensor3<f32> = wx.cast_to();
    // numerics gate on every bench run: the f32 tier must track the f64
    // tier to single-precision accumulation error
    {
        let y64 = we1.run(&wx).y;
        let y32 = Engine::with_workers(wplan32.clone(), 1).run(&wx32).y;
        let scale = y64.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        let rel = y32.cast_to::<f64>().max_abs_diff(&y64) / scale;
        assert!(rel < 1e-3, "f32 tier diverged from the f64 tier: rel {rel}");
    }
    let we32_1 = Engine::with_workers(wplan32.clone(), 1);
    let we32_n = Engine::new(wplan32.clone());
    let m_f32_1 = wb.run("winograd: DCGAN-paper, f32 fast path, 1 worker", || {
        black_box(we32_1.run(&wx32).y.data.len())
    });
    let m_f32_n = wb.run(
        &format!("winograd: DCGAN-paper, f32 fast path, {} workers", we32_n.workers()),
        || black_box(we32_n.run(&wx32).y.data.len()),
    );
    println!("{}", speedup_line("f32 fast path vs f64 reference (1 worker)", &m_batch1, &m_f32_1));
    println!("{}", speedup_line("f32 fast path vs f64 reference (parallel)", &m_batchn, &m_f32_n));
    println!(
        "  -> f32 throughput: {:.0} tiles/s (1 worker), {:.0} tiles/s ({} workers)",
        m_f32_1.throughput(tiles_per_run as usize),
        m_f32_n.throughput(tiles_per_run as usize),
        we32_n.workers(),
    );
    report.record(&m_f32_1);
    report.record_as("winograd: DCGAN-paper, f32 fast path, parallel", &m_f32_n);
    report.metric("f32_vs_f64_speedup_1w", speedup(&m_batch1, &m_f32_1));
    report.metric("f32_vs_f64_speedup_parallel", speedup(&m_batchn, &m_f32_n));
    report.metric("f32_tiles_per_sec_1w", m_f32_1.throughput(tiles_per_run as usize));
    report.metric("f32_tiles_per_sec_parallel", m_f32_n.throughput(tiles_per_run as usize));
    report.metric("f64_tiles_per_sec_1w", m_batch1.throughput(tiles_per_run as usize));
    report.metric("f64_tiles_per_sec_parallel", m_batchn.throughput(tiles_per_run as usize));

    // --- kernel dispatch: explicit SIMD vs the blocked scalar loop -------
    // PR 6's tentpole: the Winograd GEMM dispatches to an arch-specific
    // micro-kernel (AVX2/NEON, mul-then-add in the same ascending-c_in
    // order — no FMA) compiled into the plan. The contract is *bitwise*
    // identity at f64, so the head-to-head is pure throughput: same plan,
    // same data, scalar vs SIMD dispatch, at 1 and N workers.
    let simd_kind = if simd_available() { KernelKind::Simd } else { KernelKind::Scalar };
    if !simd_available() {
        println!("(host has no AVX2/NEON: the simd legs below dispatch the scalar kernel)");
    }
    let kplanner = |kernel| {
        Planner::new(PlanOptions {
            select: Select::Force(Method::Winograd),
            kernel: wingan::engine::KernelSelect::Force(kernel),
            ..Default::default()
        })
    };
    let kscalar = Arc::new(kplanner(KernelKind::Scalar).compile_seeded(&zoo::dcgan(Scale::Paper), 7));
    let ksimd = Arc::new(kplanner(simd_kind).compile_seeded(&zoo::dcgan(Scale::Paper), 7));
    // the acceptance gate, checked on every bench run: kernel choice must
    // never change the f64 bits, at any worker count
    for workers in [1usize, wen.workers()] {
        let ys = Engine::with_workers(kscalar.clone(), workers).run(&wx).y;
        let yv = Engine::with_workers(ksimd.clone(), workers).run(&wx).y;
        assert_eq!(
            ys.max_abs_diff(&yv),
            0.0,
            "scalar and simd kernels must agree bit for bit ({workers} workers)"
        );
    }
    let ks1 = Engine::with_workers(kscalar.clone(), 1);
    let kv1 = Engine::with_workers(ksimd.clone(), 1);
    let ksn = Engine::new(kscalar.clone());
    let kvn = Engine::new(ksimd.clone());
    let m_ks1 = wb.run("kernel: DCGAN-paper f64, scalar dispatch, 1 worker", || {
        black_box(ks1.run(&wx).y.data.len())
    });
    let m_kv1 = wb.run("kernel: DCGAN-paper f64, simd dispatch, 1 worker", || {
        black_box(kv1.run(&wx).y.data.len())
    });
    let m_ksn = wb.run(
        &format!("kernel: DCGAN-paper f64, scalar dispatch, {} workers", ksn.workers()),
        || black_box(ksn.run(&wx).y.data.len()),
    );
    let m_kvn = wb.run(
        &format!("kernel: DCGAN-paper f64, simd dispatch, {} workers", kvn.workers()),
        || black_box(kvn.run(&wx).y.data.len()),
    );
    println!("{}", speedup_line("simd vs scalar kernel (1 worker)", &m_ks1, &m_kv1));
    println!("{}", speedup_line("simd vs scalar kernel (parallel)", &m_ksn, &m_kvn));
    report.record(&m_ks1);
    report.record(&m_kv1);
    report.metric("simd_vs_scalar_speedup_1w", speedup(&m_ks1, &m_kv1));
    report.metric("simd_vs_scalar_speedup_parallel", speedup(&m_ksn, &m_kvn));
    report.metric("simd_available", if simd_available() { 1.0 } else { 0.0 });

    // micro head-to-head on one paper-scale slab: the dispatched GEMM alone
    // (no transforms, no gather), scalar vs SIMD over the widest layer
    let klp = wplan
        .layers
        .iter()
        .filter(|lp| lp.method == Method::Winograd && !lp.reordered.is_empty())
        .max_by_key(|lp| lp.layer.c_in * lp.layer.c_out)
        .expect("paper DCGAN has winograd layers");
    let krf = &klp.reordered[0];
    let ktiles = klp.tiles.tiles_w;
    let kv = rng.normal_vec(16 * krf.c_in * ktiles);
    let mut km = vec![0.0f64; krf.c_out * 16 * ktiles];
    let m_micro_s = wb.run(
        &format!("kernel micro: multiply_batch scalar ({}x{}, {ktiles} tiles)", krf.c_in, krf.c_out),
        || black_box(multiply_batch(KernelKind::Scalar, krf, &kv, ktiles, &mut km)),
    );
    let m_micro_v = wb.run(
        &format!("kernel micro: multiply_batch simd ({}x{}, {ktiles} tiles)", krf.c_in, krf.c_out),
        || black_box(multiply_batch(simd_kind, krf, &kv, ktiles, &mut km)),
    );
    println!("{}", speedup_line("simd vs scalar kernel (micro GEMM)", &m_micro_s, &m_micro_v));
    report.metric("simd_vs_scalar_speedup_micro", speedup(&m_micro_s, &m_micro_v));

    // --- runtime zero-skip: dense slab vs injected dead c_in runs --------
    // PR 6's sparsity leg: the run-list lets the GEMM skip whole dead
    // c_in ranges per (position, c_out block). Kill ~1/4 of each block's
    // channels and compare against the dense walk over the *same* zeroed
    // slab — values must match exactly, work must drop.
    {
        let mut sparse_rf = krf.clone();
        let (c_in, c_out, n_live) = (sparse_rf.c_in, sparse_rf.c_out, sparse_rf.live.len());
        let dead = c_in / 4;
        for pi in 0..n_live {
            let lo = (pi * 7) % (c_in - dead + 1);
            for co in 0..c_out {
                for ci in lo..lo + dead {
                    sparse_rf.u[(pi * c_out + co) * c_in + ci] = 0.0;
                }
            }
        }
        let mut dense_rf = sparse_rf.clone();
        dense_rf.skip = None;
        sparse_rf.skip = RunList::build(n_live, c_out, c_in, &sparse_rf.u);
        let sk = sparse_rf.skip.as_ref().expect("injected runs must surface");
        let frac = sk.skipped_products(c_out, c_in) as f64 / (n_live * c_out * c_in) as f64;
        let mut md = vec![0.0f64; c_out * 16 * ktiles];
        let mut ms = vec![0.0f64; c_out * 16 * ktiles];
        let dense_mults = multiply_batch(simd_kind, &dense_rf, &kv, ktiles, &mut md);
        let sparse_mults = multiply_batch(simd_kind, &sparse_rf, &kv, ktiles, &mut ms);
        assert_eq!(md, ms, "zero-skip must not change the values");
        assert!(sparse_mults < dense_mults, "zero-skip must elide work");
        let m_dense = wb.run(
            &format!("kernel micro: dense walk over {:.0}%-dead slab", frac * 100.0),
            || black_box(multiply_batch(simd_kind, &dense_rf, &kv, ktiles, &mut md)),
        );
        let m_sparse = wb.run("kernel micro: zero-skip over the same slab", || {
            black_box(multiply_batch(simd_kind, &sparse_rf, &kv, ktiles, &mut ms))
        });
        println!("{}", speedup_line("zero-skip vs dense on a 1/4-dead slab", &m_dense, &m_sparse));
        println!(
            "  -> zero-skip elides {:.1}% of products ({} of {} per tile)",
            frac * 100.0,
            sk.skipped_products(c_out, c_in),
            n_live * c_out * c_in,
        );
        report.record(&m_dense);
        report.record(&m_sparse);
        report.metric("sparse_vs_dense_speedup", speedup(&m_dense, &m_sparse));
        report.metric("sparse_dead_fraction", frac);
    }

    // --- plan artifacts: AOT compile vs warm artifact load ---------------
    // PR 5's cold-start story: `wingan serve --plan-store` replaces the
    // startup recompile (phase decomposition + G g Gᵀ transforms + reorder
    // + DSE race, per route) with one file read + checksum + decode. This
    // is the head-to-head on the same paper-scale DCGAN winograd plan the
    // sections above execute.
    let store_dir =
        std::env::temp_dir().join(format!("wingan-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = PlanStore::open(&store_dir);
    let wkey = PlanKey::new("dcgan", Scale::Paper, Precision::F64, "winograd", 7);
    store.publish(&wkey, &*wplan).expect("publish paper-scale plan artifact");
    // round-trip gate on every bench run: the loaded plan must execute
    // bit-identically to the freshly compiled one
    {
        let loaded = match store.load_uncached(&wkey).expect("load paper-scale artifact") {
            AnyPlan::F64(p) => p,
            AnyPlan::F32(_) => unreachable!("published f64"),
        };
        let y_loaded = Engine::with_workers(loaded, 1).run(&wx).y;
        assert_eq!(
            y_loaded.max_abs_diff(&we1.run(&wx).y),
            0.0,
            "artifact round trip must be bitwise invisible"
        );
    }
    let m_plan_build = wb.run("plan: cold compile DCGAN-paper (winograd route)", || {
        black_box(wplanner.compile_seeded(&zoo::dcgan(Scale::Paper), 7).layers.len())
    });
    let m_plan_load = wb.run("plan: artifact load DCGAN-paper (read+checksum+decode)", || {
        black_box(store.load_uncached(&wkey).expect("artifact load").n_layers())
    });
    println!(
        "{}",
        speedup_line("artifact load vs cold compile (startup path)", &m_plan_build, &m_plan_load)
    );
    report.record(&m_plan_build);
    report.record(&m_plan_load);
    report.metric("plan_build_ns", m_plan_build.median() * 1e9);
    report.metric("artifact_load_ns", m_plan_load.median() * 1e9);
    report.metric("artifact_load_speedup", speedup(&m_plan_build, &m_plan_load));
    let _ = std::fs::remove_dir_all(&store_dir);

    // --- pool: spawn-overhead elimination --------------------------------
    // PR 1 spawned scoped threads per phase per layer per request; the
    // persistent pool pays thread creation once at startup. Near-empty
    // chunks make the dispatch overhead itself the measured quantity: the
    // baseline spawns 3 threads per call (chunk 0 runs on the caller, as
    // the old run_chunked did), the pool queues 3 jobs per call.
    let pool = WorkerPool::shared(4);
    let m_spawn = b.run("dispatch: scoped spawn per call (PR-1 style)", || {
        std::thread::scope(|scope| {
            let handles: Vec<_> =
                (1..4usize).map(|i| scope.spawn(move || black_box(i * i))).collect();
            black_box(0usize) + handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
        })
    });
    let m_pool = b.run("dispatch: persistent pool, same 4 chunks", || {
        pool.run_chunked(4, 4, |s, _e| black_box(s * s)).into_iter().sum::<usize>()
    });
    println!("{}", speedup_line("spawn-overhead elimination", &m_spawn, &m_pool));

    // --- engine: batch-level scaling vs sequential samples ---------------
    // the serving path executes whole buckets through Engine::run_batch;
    // sample-level scheduling keeps every worker on a whole sample (no
    // per-layer barrier), the sequential baseline is PR 1's run_batch
    // (samples one after another, stripes parallel inside each).
    let batch: Vec<Tensor3> = (0..8)
        .map(|_| Tensor3::from_vec(ci0, h0, w0, rng.normal_vec(ci0 * h0 * w0)))
        .collect();
    let bq = Bench::quick();
    let m_seq = bq.run("engine: batch of 8, sequential samples (stripe-level)", || {
        black_box(en.run_batch_with(&batch, BatchSchedule::StripeLevel).len())
    });
    let m_smp = bq.run("engine: batch of 8, sample-level on shared pool", || {
        black_box(en.run_batch_with(&batch, BatchSchedule::SampleLevel).len())
    });
    println!("{}", speedup_line("batch-level scaling vs sequential samples", &m_seq, &m_smp));
    println!("  -> sample-level serving throughput: {:.1} img/s (batch 8)", m_smp.throughput(8));

    // cycle simulator
    let cfg = AccelConfig::default();
    let models = zoo::all(Scale::Paper);
    b.run("cycle sim: 4 models x 3 methods", || {
        let mut acc = 0.0;
        for g in &models {
            for m in Method::ALL {
                acc += simulate_model(g, m, &cfg, true).t_total;
            }
        }
        black_box(acc)
    });

    // batcher state machine
    b.run("batcher: push+poll 256 requests (buckets 1/4/8)", || {
        let mut batcher =
            DynamicBatcher::new(BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(5)));
        let t = Instant::now();
        let mut out = 0usize;
        for i in 0..256 {
            batcher.push(GenRequest {
                id: i,
                model: "dcgan".into(),
                method: "winograd".into(),
                input: Vec::new(),
                enqueued: t,
                deadline: None,
                trace: 0,
            });
            while let Some(ready) = batcher.poll(t) {
                out += ready.requests.len();
            }
        }
        while let Some(ready) = batcher.flush() {
            out += ready.requests.len();
        }
        black_box(out)
    });

    // continuous scheduler state machine: same 256-request stream through
    // admit + work-conserving poll (the PR-7 production path)
    b.run("continuous batcher: admit+poll 256 requests (buckets 1/4/8)", || {
        let mut batcher =
            ContinuousBatcher::new(BatchPolicy::new(vec![1, 4, 8], Duration::ZERO), 512);
        let t = Instant::now();
        let mut out = 0usize;
        for i in 0..256 {
            batcher
                .admit(
                    GenRequest {
                        id: i,
                        model: "dcgan".into(),
                        method: "winograd".into(),
                        input: Vec::new(),
                        enqueued: t,
                        deadline: None,
                        trace: 0,
                    },
                    t,
                )
                .unwrap();
            loop {
                let d = batcher.poll(t);
                out += d.shed.len();
                match d.batch {
                    Some(ready) => out += ready.requests.len(),
                    None => break,
                }
            }
        }
        while let Some(ready) = batcher.flush() {
            out += ready.requests.len();
        }
        black_box(out)
    });

    // JSON substrate (manifest-sized doc)
    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = &manifest_text {
        b.run("json: parse artifact manifest", || {
            black_box(wingan::util::json::parse(text).unwrap())
        });
    }

    // PJRT execute path (only when artifacts AND the backend are present)
    match wingan::runtime::Manifest::load(std::path::Path::new("artifacts"))
        .and_then(|m| wingan::runtime::Runtime::new().map(|rt| (m, rt)))
    {
        Ok((m, mut rt)) => {
            let entry = m.find("deconv_k5s2").expect("layer artifact").clone();
            rt.load(&entry).expect("compile");
            let input = rng.normal_vec_f32(entry.input_len());
            b.run("pjrt: execute deconv_k5s2 (8->16ch, 8x8)", || {
                black_box(rt.execute("deconv_k5s2", &input).unwrap().len())
            });
            if let Some(e) = m.find("dcgan_b8") {
                let e = e.clone();
                rt.load(&e).expect("compile");
                let input = rng.normal_vec_f32(e.input_len());
                let bq = Bench { budget: Duration::from_secs(2), ..Bench::default() };
                let meas = bq.run("pjrt: execute dcgan_b8 generator", || {
                    black_box(rt.execute("dcgan_b8", &input).unwrap().len())
                });
                println!(
                    "  -> serving-side throughput ceiling: {:.1} img/s (batch 8)",
                    8.0 / meas.median()
                );
            }
        }
        Err(e) => println!("(skipping PJRT benches: {e})"),
    }

    // machine-readable perf trajectory (ROADMAP north-star): ns/iter,
    // tiles/sec, and the headline speedups, uploaded as a CI artifact
    report.record(&m_seq);
    report.record(&m_smp);
    report.metric("batch8_sample_level_speedup", speedup(&m_seq, &m_smp));
    let path = std::path::Path::new("BENCH_pr6.json");
    report.write(path).expect("write bench trajectory json");
    println!("wrote {} (perf trajectory)", path.display());
}
