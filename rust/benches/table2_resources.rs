//! Bench: reproduce **Table II** — resource utilisation for DCGAN on the
//! Virtex7-485T (ours vs the TDC baseline [14]) — plus per-model resource
//! reports and the model-vs-paper error summary.

use wingan::accel::AccelConfig;
use wingan::benchlib::{black_box, Bench};
use wingan::gan::workload::Method;
use wingan::gan::zoo::{self, Scale};
use wingan::report;
use wingan::resource;

fn main() {
    println!("==========================================================");
    println!(" Table II reproduction — FPGA resource utilisation");
    println!("==========================================================");
    let cfg = AccelConfig::default();
    print!("{}", report::table2(&cfg));

    let g = zoo::dcgan(Scale::Paper);
    let ours = resource::report(&g, &cfg, Method::Winograd);
    let tdc = resource::report(&g, &cfg, Method::Tdc);
    let p14 = resource::PAPER_TABLE2_TDC;
    let po = resource::PAPER_TABLE2_OURS;
    let err = |m: usize, p: usize| 100.0 * (m as f64 - p as f64) / p as f64;
    println!("\nmodel error vs paper:");
    println!(
        "  [14]: BRAM {:+.1}%  DSP {:+.1}%  LUT {:+.1}%  FF {:+.1}%",
        err(tdc.bram18k, p14.bram18k),
        err(tdc.dsp48e, p14.dsp48e),
        err(tdc.lut, p14.lut),
        err(tdc.ff, p14.ff)
    );
    println!(
        "  ours: BRAM {:+.1}%  DSP {:+.1}%  LUT {:+.1}%  FF {:+.1}%",
        err(ours.bram18k, po.bram18k),
        err(ours.dsp48e, po.dsp48e),
        err(ours.lut, po.lut),
        err(ours.ff, po.ff)
    );

    println!("\nper-model resource estimates (Winograd design):");
    for g in zoo::all(Scale::Paper) {
        let r = resource::report(&g, &cfg, Method::Winograd);
        println!(
            "  {:<10} BRAM18K {:>5}  DSP48E {:>5}  LUT {:>7}  FF {:>7}",
            g.name, r.bram18k, r.dsp48e, r.lut, r.ff
        );
    }

    println!("\n-- timings --");
    let b = Bench::default();
    b.run("table2: full resource report", || {
        black_box(resource::report(&g, &cfg, Method::Winograd).bram18k)
    });
}
