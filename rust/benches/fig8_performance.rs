//! Bench: reproduce **Fig. 8** — DeConv performance of the zero-padded,
//! TDC and Winograd accelerators on DCGAN / ArtGAN / DiscoGAN / GP-GAN —
//! plus ablations (zero-skip ZP baseline, bandwidth sensitivity) and
//! timing of the cycle simulator itself.

use wingan::accel::{simulate_model, AccelConfig};
use wingan::benchlib::{black_box, Bench};
use wingan::gan::workload::Method;
use wingan::gan::zoo::{self, Scale};
use wingan::report;

fn main() {
    println!("==========================================================");
    println!(" Fig. 8 reproduction — accelerator performance comparison");
    println!("==========================================================");
    let cfg = AccelConfig::default();
    print!("{}", report::fig8(&cfg));

    // ablation: GANAX-style zero-skipping for the zero-padded baseline
    // (paper sec. V.B mentions the technique and why it still trails TDC)
    println!("\nablation — zero-padded baseline with activation zero-skip:");
    let skip_cfg = cfg.with_zero_skip(true);
    for g in zoo::all(Scale::Paper) {
        let zp = simulate_model(&g, Method::ZeroPadded, &cfg, true);
        let zs = simulate_model(&g, Method::ZeroPadded, &skip_cfg, true);
        let wi = simulate_model(&g, Method::Winograd, &cfg, true);
        println!(
            "  {:<10} plain {:>8.3} ms  skip {:>8.3} ms  ours {:>8.3} ms  (ours vs skip: {:.2}x)",
            g.name,
            zp.t_total * 1e3,
            zs.t_total * 1e3,
            wi.t_total * 1e3,
            zs.t_total / wi.t_total
        );
    }

    // ablation: bandwidth sensitivity (eq. 6/7 — where does the winograd
    // engine become transfer-bound?)
    println!("\nablation — bandwidth sweep (DCGAN, Winograd):");
    let g = zoo::dcgan(Scale::Paper);
    for gbps in [0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
        let c = cfg.with_bandwidth(gbps * 1e9);
        let sim = simulate_model(&g, Method::Winograd, &c, true);
        println!(
            "  {gbps:>5.1} GB/s  t={:>8.3} ms  compute {:>8.3} ms  transfer {:>8.3} ms  {}",
            sim.t_total * 1e3,
            sim.layers.iter().map(|l| l.t_compute).sum::<f64>() * 1e3,
            sim.layers.iter().map(|l| l.t_transfer).sum::<f64>() * 1e3,
            if sim.layers.iter().map(|l| l.t_transfer).sum::<f64>()
                > sim.layers.iter().map(|l| l.t_compute).sum::<f64>()
            {
                "transfer-bound"
            } else {
                "compute-bound"
            }
        );
    }

    println!("\n-- timings --");
    let b = Bench::default();
    let models = zoo::all(Scale::Paper);
    b.run("fig8: cycle-sim one model x one method", || {
        black_box(simulate_model(&models[0], Method::Winograd, &cfg, true).t_total)
    });
    b.run("fig8: full table (4 models x 3 methods)", || {
        let mut acc = 0.0;
        for g in &models {
            for m in Method::ALL {
                acc += simulate_model(g, m, &cfg, true).t_total;
            }
        }
        black_box(acc)
    });
}
