//! Bench: reproduce **Fig. 9** — energy consumption of the DeConv layers
//! relative to the zero-padded baseline — with the per-component breakdown
//! and a sensitivity sweep over the energy parameters.

use wingan::accel::{simulate_model, AccelConfig};
use wingan::benchlib::{black_box, Bench};
use wingan::energy::{energy_of, fig9_row, EnergyParams};
use wingan::gan::workload::Method;
use wingan::gan::zoo::{self, Scale};
use wingan::report;

fn main() {
    println!("==========================================================");
    println!(" Fig. 9 reproduction — DeConv energy consumption");
    println!("==========================================================");
    let cfg = AccelConfig::default();
    let ep = EnergyParams::default();
    print!("{}", report::fig9(&cfg, &ep));

    println!("\nbreakdown (DCGAN, per method, mJ):");
    let g = zoo::dcgan(Scale::Paper);
    for m in Method::ALL {
        let sim = simulate_model(&g, m, &cfg, true);
        let b = energy_of(&sim, &g, &ep);
        println!(
            "  {:<16} compute {:>7.3}  onchip {:>7.3}  offchip {:>7.3}  rearrange {:>7.3}  total {:>7.3}",
            m.label(),
            b.compute * 1e3,
            b.onchip * 1e3,
            b.offchip * 1e3,
            b.rearrange * 1e3,
            b.total() * 1e3
        );
    }

    // the paper's sec. V.C limitation: rearrangement overhead caps the saving
    println!("\nsensitivity — mean saving vs zero-padded under parameter sweeps:");
    for (label, mutate) in [
        ("default", Box::new(|_: &mut EnergyParams| {}) as Box<dyn Fn(&mut EnergyParams)>),
        ("dram 2x (DDR3 interface-heavy)", Box::new(|e: &mut EnergyParams| e.dram_word *= 2.0)),
        ("sram 2x (small banks)", Box::new(|e: &mut EnergyParams| e.sram_word *= 2.0)),
        ("no weight amortisation", Box::new(|e: &mut EnergyParams| e.weight_reuse_frames = 1.0)),
        ("zero-toggle 0.0 (ideal gating)", Box::new(|e: &mut EnergyParams| e.zero_toggle_fraction = 0.0)),
        ("zero-toggle 1.0 (no gating)", Box::new(|e: &mut EnergyParams| e.zero_toggle_fraction = 1.0)),
    ] {
        let mut p = EnergyParams::default();
        mutate(&mut p);
        let models = zoo::all(Scale::Paper);
        let mean: f64 = models.iter().map(|g| fig9_row(g, &cfg, &p).saving_vs_zp()).sum::<f64>()
            / models.len() as f64;
        let mean_t: f64 = models.iter().map(|g| fig9_row(g, &cfg, &p).saving_vs_tdc()).sum::<f64>()
            / models.len() as f64;
        println!("  {label:<34} mean vs ZP {mean:>5.2}x   vs TDC {mean_t:>5.2}x");
    }

    println!("\n-- timings --");
    let b = Bench::default();
    let models = zoo::all(Scale::Paper);
    b.run("fig9: energy row, one model (3 sims)", || {
        black_box(fig9_row(&models[0], &cfg, &ep).saving_vs_zp())
    });
    b.run("fig9: full table", || {
        let mut acc = 0.0;
        for g in &models {
            acc += fig9_row(g, &cfg, &ep).saving_vs_zp();
        }
        black_box(acc)
    });
}
