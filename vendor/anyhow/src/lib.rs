//! Minimal, API-compatible subset of the `anyhow` crate for offline builds.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait. Swapping in the real `anyhow` is a one-line Cargo.toml
//! change; nothing here depends on shim-specific behaviour.

use std::fmt;

/// A type-erased error with a human-readable context chain.
///
/// Unlike the real `anyhow::Error` this stores the rendered message chain
/// rather than the live error values — enough for every use in this
/// workspace (all errors end up displayed, never downcast).
pub struct Error {
    /// innermost cause first; contexts are pushed at the front when added
    chain: Vec<String>,
}

impl Error {
    /// Create an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach outer context (rendered like anyhow's `{:#}` chain).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` shows the outermost context; `{:#}` shows the whole chain,
        // matching how anyhow is conventionally printed.
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// NOTE: method generics here must match the trait declaration exactly.

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")
            .map(|_| ())
            .with_context(|| "reading config".to_string())
    }

    #[test]
    fn context_chain_renders_outermost_then_full() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        assert!(full.len() > "reading config: ".len());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_compile_and_format() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
