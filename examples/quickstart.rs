//! Quickstart: the smallest end-to-end use of the system.
//!
//! 1. Load the artifact manifest (`make artifacts` builds it).
//! 2. Compile one AOT single-layer Winograd-DeConv op on the PJRT CPU
//!    client and run it on a random input.
//! 3. Cross-check the PJRT result against the pure-rust reference deconv
//!    (same math, different stack) and against the shipped jax golden.
//!
//! Run with: `cargo run --release --example quickstart`

use std::path::Path;
use wingan::runtime::{Manifest, Runtime};
use wingan::tdc;
use wingan::util::bin;
use wingan::util::tensor::{Filter4, Tensor3};

fn main() -> anyhow::Result<()> {
    // --- 1. artifacts -----------------------------------------------------
    let manifest = Manifest::load(Path::new("artifacts"))?;
    println!("manifest: {} artifacts (scale={})", manifest.entries.len(), manifest.scale);

    let entry = manifest
        .find("deconv_k5s2")
        .expect("deconv_k5s2 artifact missing — run `make artifacts`")
        .clone();

    // --- 2. compile + execute on PJRT -------------------------------------
    let mut rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());
    rt.load(&entry)?;

    let x = bin::read_f32(&entry.golden_input)?;
    let y = rt.execute(&entry.name, &x)?;
    println!(
        "executed {}: input {:?} -> output {:?} ({} values)",
        entry.name,
        entry.input_shape,
        entry.output_shape,
        y.len()
    );

    // --- 3a. golden check (rust/PJRT vs jax) ------------------------------
    let golden = bin::read_f32(&entry.golden_output)?;
    let diff_jax = bin::max_abs_diff(&y, &golden);
    println!("max |PJRT - jax golden| = {diff_jax:.2e}");
    anyhow::ensure!(diff_jax < 2e-4, "golden mismatch");

    // --- 3b. independent reference: pure-rust standard deconv -------------
    // The artifact bakes seeded weights (see python/compile/aot.py); rebuild
    // them here with the same derivation and compare end to end.
    let (c_in, c_out, k, s) = (8usize, 16usize, 5usize, 2usize);
    let p = tdc::default_padding(k, s);
    // aot.py draws weights from default_rng(42): standard_normal(c_in,c_out,k,k)
    // — we can't replay numpy's generator here, so instead run the check in
    // the other direction: feed the PJRT op a delta input and compare the
    // response against the rust TDC/winograd equivalence on the *same*
    // function family (structure check), plus verify TDC == naive on random
    // rust-side weights (math check).
    let mut rng = wingan::util::prng::Rng::new(1);
    let xt = Tensor3::from_vec(c_in, 8, 8, rng.normal_vec(c_in * 64));
    let wt = Filter4::from_vec(c_in, c_out, k, k, rng.normal_vec(c_in * c_out * k * k));
    let y_naive = tdc::deconv_naive(&xt, &wt, s, p);
    let y_tdc = tdc::tdc_deconv(&xt, &wt, s, p);
    let y_fun = wingan::accel::functional::run_winograd_deconv(&xt, &wt, s, p);
    println!(
        "rust math check: |TDC - naive| = {:.2e}, |winograd-dataflow - naive| = {:.2e}",
        y_naive.max_abs_diff(&y_tdc),
        y_naive.max_abs_diff(&y_fun.y)
    );
    anyhow::ensure!(y_naive.max_abs_diff(&y_fun.y) < 1e-9);

    println!("\nquickstart OK — all three stacks agree.");
    Ok(())
}
