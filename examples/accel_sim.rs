//! Accelerator-simulation walkthrough: run all three DeConv accelerators
//! over the Table-I GAN zoo, print per-layer detail for one model, and
//! demonstrate the functional simulator's bit-exactness on real tensors.
//!
//! Run with: `cargo run --release --example accel_sim [-- --model dcgan]`

use wingan::accel::functional::{run_tdc_deconv, run_winograd_deconv};
use wingan::accel::{simulate_model, AccelConfig};
use wingan::cli::Args;
use wingan::gan::workload::Method;
use wingan::gan::zoo::{self, Scale};
use wingan::tdc;
use wingan::util::prng::Rng;
use wingan::util::tensor::{Filter4, Tensor3};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    // examples take flags only; a stray bare word is a forgotten flag name
    args.reject_bare_args().map_err(anyhow::Error::msg)?;
    let wanted = args.get_or("model", "dcgan").to_string();
    let cfg = AccelConfig::default();

    // --- headline table ----------------------------------------------------
    println!("{}", wingan::report::fig8(&cfg));

    // --- per-layer detail for one model -------------------------------------
    let g = zoo::all(Scale::Paper)
        .into_iter()
        .find(|g| g.name.eq_ignore_ascii_case(&wanted))
        .unwrap_or_else(|| zoo::dcgan(Scale::Paper));
    println!("per-layer detail — {} (Winograd engine):", g.name);
    let sim = simulate_model(&g, Method::Winograd, &cfg, true);
    for (i, (l, ls)) in g.deconv_layers().zip(sim.layers.iter()).enumerate() {
        println!(
            "  L{i}: {}x{}x{}x{} K={} S={}  t={:.4} ms (compute {:.4}, transfer {:.4}, prologue {:.4})  {}",
            l.c_in,
            l.c_out,
            l.h_in,
            l.w_in,
            l.k,
            l.s,
            ls.t_total * 1e3,
            ls.t_compute * 1e3,
            ls.t_transfer * 1e3,
            ls.t_prologue * 1e3,
            if ls.t_transfer > ls.t_compute { "transfer-bound" } else { "compute-bound" }
        );
    }

    // --- functional simulator equivalence (Fig. 2 claim on real tensors) ---
    println!("\nfunctional dataflow equivalence (random tensors):");
    let mut rng = Rng::new(2024);
    for (k, s) in [(5usize, 2usize), (4, 2), (3, 1)] {
        let p = tdc::default_padding(k, s);
        let x = Tensor3::from_vec(6, 10, 12, rng.normal_vec(6 * 10 * 12));
        let w = Filter4::from_vec(6, 4, k, k, rng.normal_vec(6 * 4 * k * k));
        let want = tdc::deconv_naive(&x, &w, s, p);
        let win = run_winograd_deconv(&x, &w, s, p);
        let td = run_tdc_deconv(&x, &w, s, p);
        println!(
            "  K={k} S={s}: |winograd - standard| = {:.2e}, |tdc - standard| = {:.2e}, \
             mults winograd/tdc = {}/{} ({:.0}% skipped)",
            want.max_abs_diff(&win.y),
            want.max_abs_diff(&td.y),
            win.events.mults,
            td.events.mults,
            100.0 * (1.0 - win.events.mults as f64 / td.events.mults as f64)
        );
        anyhow::ensure!(want.max_abs_diff(&win.y) < 1e-9);
    }

    println!("\naccel_sim OK");
    Ok(())
}
