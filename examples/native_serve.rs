//! Native end-to-end serving walkthrough — no PJRT, no artifacts.
//!
//! 1. Compile whole-generator plans for one zoo model (Planner: TDC phase
//!    decomposition + Winograd filter transforms + sparsity reorder, once).
//! 2. Bring up the serving coordinator on the native engine backend.
//! 3. Push a batched request stream through the dynamic batcher.
//! 4. A/B the winograd route against the tdc route (the bit-exact
//!    standard-DeConv reference datapath) on identical inputs.
//!
//! Run with:
//! `cargo run --release --example native_serve [-- --model dcgan --requests 32 --workers 4 --precision f32]`
//!
//! `--workers` sizes the one persistent worker pool every route's engine
//! shares (0/absent = `WINGAN_WORKERS` env, then one thread per core).
//! `--precision` picks the fast routes' serving tier (f32/f64; absent =
//! `WINGAN_PRECISION` env, then the per-model dse recommendation).

use std::time::{Duration, Instant};
use wingan::cli::Args;
use wingan::coordinator::{Coordinator, ServeConfig};
use wingan::engine::{model_id, resolve_workers, NativeConfig, Planner};
use wingan::gan::zoo::{self, Scale};
use wingan::util::bin;
use wingan::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    // examples take flags only; a stray bare word is a forgotten flag name
    args.reject_bare_args().map_err(anyhow::Error::msg)?;
    let model = model_id(args.get_or("model", "dcgan"));
    let n_requests = args.get_usize("requests", 32).map_err(anyhow::Error::msg)?;
    let workers = args.get_workers().map_err(anyhow::Error::msg)?;
    let precision = args.get_precision().map_err(anyhow::Error::msg)?;

    // --- 0. what does the plan compiler decide? ----------------------------
    let g = zoo::all(Scale::Small)
        .into_iter()
        .find(|g| model_id(g.name) == model)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model}'"))?;
    let planner = Planner::default();
    let plan = planner.compile_seeded(&g, 42);
    println!(
        "== plan ({}, small scale; fast-route precision policy {:?}, dse recommends {}) ==",
        g.name,
        wingan::engine::resolve_precision(precision),
        planner.resolve_precision(&g),
    );
    for (i, lp) in plan.layers.iter().enumerate() {
        println!(
            "  L{i}: {:?} {}x{} K={} S={}  method={:?}  phases={}  live-positions={}  \
             linebuf {} rows / {} words",
            lp.layer.kind,
            lp.layer.c_in,
            lp.layer.c_out,
            lp.layer.k,
            lp.layer.s,
            lp.method,
            lp.phases.len(),
            lp.live_positions(),
            lp.linebuf_depth,
            lp.linebuf_words,
        );
    }

    // --- 1. serving coordinator on the native backend ----------------------
    let t0 = Instant::now();
    let coord = Coordinator::start_native(
        NativeConfig { scale: Scale::Small, workers, precision, ..Default::default() },
        ServeConfig {
            max_wait: Duration::from_millis(5),
            preload_models: Some(vec![model.clone()]),
            ..Default::default()
        },
    )?;
    println!(
        "\nengine ready in {:?} (plans compiled once; persistent pool of {} workers \
         shared by all routes)",
        t0.elapsed(),
        resolve_workers(workers)
    );

    let route = coord.router().route(&model, "winograd")
        .map_err(anyhow::Error::msg)?;
    let input_len = route.sample_input_len;
    println!("routes: buckets {:?}, sample in/out {}/{}",
        route.bucket_sizes(), route.sample_input_len, route.sample_output_len);

    // --- 2. request stream --------------------------------------------------
    let mut rng = Rng::new(7);
    let t_start = Instant::now();
    let pending: Vec<_> = (0..n_requests)
        .map(|_| {
            coord
                .submit(&model, "winograd", rng.normal_vec_f32(input_len))
                .map_err(anyhow::Error::msg)
        })
        .collect::<Result<_, _>>()?;
    for rx in pending {
        let resp = rx.recv()?.map_err(anyhow::Error::msg)?;
        anyhow::ensure!(resp.output.len() == route.sample_output_len, "bad output length");
    }
    let wall = t_start.elapsed().as_secs_f64();
    println!(
        "\nserved {n_requests} requests in {wall:.3}s ({:.1} img/s)",
        n_requests as f64 / wall
    );
    println!("{}", coord.metrics().report());

    // --- 3. method A/B: fast algorithm vs bit-exact reference ---------------
    let input = rng.normal_vec_f32(input_len);
    let a = coord
        .generate(&model, "winograd", input.clone())
        .map_err(anyhow::Error::msg)?;
    let b = coord
        .generate(&model, "tdc", input)
        .map_err(anyhow::Error::msg)?;
    let diff = bin::max_abs_diff(&a.output, &b.output);
    println!("max |winograd - tdc| = {diff:.2e} (same function, different fast algorithm)");
    anyhow::ensure!(diff < 1e-3, "A/B mismatch");

    coord.shutdown();
    println!("native_serve OK");
    Ok(())
}
