//! Design-space exploration walkthrough (paper §IV.C): sweep tiling
//! factors under the Virtex7-485T envelope, print the roof/bandwidth
//! table, show the cross-layer optimisation picking the paper's (4, 128),
//! and sweep the bandwidth model of eq. 7.
//!
//! Run with: `cargo run --release --example dse_explorer`

use wingan::accel::AccelConfig;
use wingan::dse::{self, VIRTEX7_485T};
use wingan::gan::zoo::{self, Scale};

fn main() {
    let models = zoo::all(Scale::Paper);

    println!("envelope: Virtex7-485T ({} DSP48E, {} BRAM18K)", VIRTEX7_485T.dsp48e, VIRTEX7_485T.bram18k);
    let points = dse::sweep(&models, &VIRTEX7_485T);
    println!("\n{}", dse::render_table(&points, 16));

    let best = dse::optimal(&models, &VIRTEX7_485T);
    println!(
        "selected design point: (T_m, T_n) = ({}, {}) — paper chose (4, 128)",
        best.t_m, best.t_n
    );

    // per-layer roof + bandwidth at the chosen point (the roofline pairs
    // the paper enumerates)
    let cfg = AccelConfig::default().with_tiles(best.t_m, best.t_n);
    println!("\nper-layer roof / bandwidth (DCGAN, Winograd engine):");
    for (i, l) in zoo::dcgan(Scale::Paper).deconv_layers().enumerate() {
        println!(
            "  L{i}: roof {:>7.1} GOP/s   bandwidth requirement {:>6.2} GB/s   C(K_C)/m^2 = {:.2}",
            dse::computational_roof(l, &cfg),
            dse::bandwidth_requirement(l, &cfg) / 1e9,
            dse::eq5_constant(l.k, l.s, l.p),
        );
    }

    // infeasible corner: show the DSP wall
    println!("\nDSP wall (5 DSP48E per f32 MAC):");
    for (tm, tn) in [(4, 128), (8, 128), (16, 128)] {
        let p = dse::evaluate(tm, tn, &models, &VIRTEX7_485T);
        println!(
            "  (T_m, T_n) = ({tm:>2}, {tn:>3}) -> {} DSP48E  {}",
            p.dsp,
            if p.feasible { "fits" } else { "EXCEEDS 2800" }
        );
    }
}
