//! End-to-end serving driver (the repository's E2E validation run —
//! recorded in EXPERIMENTS.md):
//!
//! * starts the coordinator (engine thread compiles the DCGAN-small
//!   Winograd artifacts via PJRT),
//! * verifies numerics against the jax goldens,
//! * replays a Poisson request stream through the dynamic batcher at
//!   several arrival rates, reporting latency percentiles + throughput,
//! * A/B-compares the winograd and tdc compute paths on identical inputs
//!   (same function, different fast algorithm — outputs must agree).
//!
//! Run with: `cargo run --release --example serve_gan [-- --model dcgan --requests 96]`

use std::path::Path;
use std::time::{Duration, Instant};
use wingan::cli::Args;
use wingan::coordinator::{Coordinator, ServeConfig};
use wingan::runtime::{Manifest, Runtime};
use wingan::util::bin;
use wingan::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    // examples take flags only; a stray bare word is a forgotten flag name
    args.reject_bare_args().map_err(anyhow::Error::msg)?;
    let model = args.get_or("model", "dcgan").to_string();
    let n_requests = args.get_usize("requests", 96).map_err(anyhow::Error::msg)?;
    let dir = args.get_or("artifacts", "artifacts");

    let manifest = Manifest::load(Path::new(dir))?;

    // --- 0. numerics gate: PJRT vs jax goldens on this model ---------------
    println!("== numerics gate ==");
    {
        let mut rt = Runtime::new()?;
        for e in manifest.entries.iter().filter(|e| e.model == model) {
            rt.load(e)?;
            let diff = rt.verify_golden(&e.name)?;
            println!("  {:<16} max|Δ| vs jax golden = {:.2e}", e.name, diff);
            anyhow::ensure!(diff < 2e-4, "numerics gate failed for {}", e.name);
        }
    }

    // --- 1. bring up the coordinator ---------------------------------------
    println!("\n== coordinator bring-up ==");
    let t0 = Instant::now();
    let coord = Coordinator::start(
        manifest,
        ServeConfig {
            max_wait: Duration::from_millis(10),
            preload_models: Some(vec![model.clone()]),
            ..Default::default()
        },
    )?;
    println!("engine ready in {:?} (artifacts compiled once, cached)", t0.elapsed());
    let route = coord.router().route(&model, "winograd").map_err(anyhow::Error::msg)?;
    let input_len = route.sample_input_len;
    let buckets = route.bucket_sizes();

    // --- 2. Poisson load sweep ---------------------------------------------
    println!("\n== load sweep ({n_requests} requests each, buckets {buckets:?}) ==");
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>10} {:>11} {:>10}",
        "rate(req/s)", "p50(ms)", "p95(ms)", "p99(ms)", "img/s", "batch_eff", "batches"
    );
    for rate in [50.0, 200.0, 1000.0] {
        let mut rng = Rng::new(42);
        let t_start = Instant::now();
        let mut pending = Vec::with_capacity(n_requests);
        for i in 0..n_requests {
            pending.push(
                coord
                    .submit(&model, "winograd", rng.normal_vec_f32(input_len))
                    .map_err(anyhow::Error::msg)?,
            );
            if i + 1 < n_requests {
                std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
            }
        }
        let mut lat = Vec::with_capacity(n_requests);
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv()?.map_err(anyhow::Error::msg)?;
            anyhow::ensure!(resp.output.len() == route.sample_output_len, "bad output len");
            lat.push((i, resp.queue_time + resp.exec_time));
        }
        let wall = t_start.elapsed().as_secs_f64();
        let mut ms: Vec<f64> = lat.iter().map(|(_, d)| d.as_secs_f64() * 1e3).collect();
        ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| ms[(((p / 100.0) * ms.len() as f64) as usize).min(ms.len() - 1)];
        let m = coord.metrics();
        println!(
            "{rate:<12} {:>10.2} {:>10.2} {:>10.2} {:>10.1} {:>11.2} {:>10}",
            pct(50.0),
            pct(95.0),
            pct(99.0),
            n_requests as f64 / wall,
            m.batch_efficiency(),
            m.batches
        );
    }

    // --- 3. winograd vs tdc A/B on identical inputs -------------------------
    println!("\n== method A/B (same input through both compute paths) ==");
    let mut rng = Rng::new(1234);
    let input = rng.normal_vec_f32(input_len);
    let a = coord.generate(&model, "winograd", input.clone()).map_err(anyhow::Error::msg)?;
    let b = coord.generate(&model, "tdc", input).map_err(anyhow::Error::msg)?;
    let diff = bin::max_abs_diff(&a.output, &b.output);
    println!("  max |winograd - tdc| = {diff:.2e} (same function, different fast algorithm)");
    anyhow::ensure!(diff < 2e-3, "A/B mismatch");

    println!("\n== final metrics ==");
    println!("{}", coord.metrics().report());
    coord.shutdown();
    println!("serve_gan OK");
    Ok(())
}
