"""TDC (transposed-Deconv-to-Conv) conversion in JAX.

The TDC method (paper refs [14-16], Fig. 1c/2b) turns one DeConv layer with
kernel K_D x K_D and stride S into S^2 ordinary Conv layers with kernel
K_C = ceil(K_D/S), whose outputs interleave into the S x S output phase grid.
This removes the overlapping-sum problem: each output pixel is produced by
exactly one sub-convolution.

This module is the *build-time* implementation used by the L2 model: the
decomposition runs at trace time (weights are static), and the per-phase
convolutions lower to plain XLA convs.  The Pallas fast path lives in
winograd_deconv.py; both are tested against kernels/ref.py.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import ref


def tdc_kc(k: int, s: int) -> int:
    """K_C = ceil(K_D / S) (Table I)."""
    return math.ceil(k / s)


def decompose(w: jax.Array, stride: int, padding: int):
    """Decompose DeConv filters w[C_in, C_out, K, K] into S^2 Conv banks.

    Returns ``(g, d0)``: ``g[S, S, C_in, C_out, K_C, K_C]`` correlation
    filters and ``d0[S, S, 2]`` (numpy) input offsets.  Pure indexing --
    differentiable and cheap; runs at trace time in the AOT path."""
    c_in, c_out, k, _ = w.shape
    s = stride
    kc = tdc_kc(k, s)
    wf = w[:, :, ::-1, ::-1]
    banks = []
    d0 = np.zeros((s, s, 2), dtype=np.int64)
    for py in range(s):
        taps_y, d0y = ref.tdc_phase_taps_1d(k, s, padding, py)
        row = []
        for px in range(s):
            taps_x, d0x = ref.tdc_phase_taps_1d(k, s, padding, px)
            d0[py, px] = (d0y, d0x)
            cols = []
            for ty in taps_y:
                line = []
                for tx in taps_x:
                    if ty < 0 or tx < 0:
                        line.append(jnp.zeros((c_in, c_out), w.dtype))
                    else:
                        line.append(wf[:, :, ty, tx])
                cols.append(jnp.stack(line, axis=-1))  # [ci, co, kc]
            row.append(jnp.stack(cols, axis=-2))  # [ci, co, kc, kc]
        banks.append(jnp.stack(row))  # [s, ci, co, kc, kc]
    g = jnp.stack(banks)  # [s, s, ci, co, kc, kc]
    return g, d0


def phase_pad(x: jax.Array, d0yx, kc: int) -> jax.Array:
    """Pad x[C,H,W] so a valid K_C-tap correlation yields exactly H x W
    outputs for the phase with input offset ``d0yx = (d0y, d0x)``."""
    d0y, d0x = int(d0yx[0]), int(d0yx[1])
    ly, lx = -d0y, -d0x
    ry, rx = kc - 1 + d0y, kc - 1 + d0x
    return jnp.pad(x, ((0, 0), (ly, ry), (lx, rx)))


def correlate_valid(x: jax.Array, g: jax.Array) -> jax.Array:
    """Valid correlation x[C_in,H,W] * g[C_in,C_out,K,K] -> [C_out,H',W']."""
    lhs = x[None]  # NCHW
    rhs = jnp.transpose(g, (1, 0, 2, 3))  # OIHW
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def interleave_phases(phases, stride: int) -> jax.Array:
    """Assemble per-phase maps y[p_y][p_x] = [C,H,W] into [C, S*H, S*W]."""
    s = stride
    rows = [jnp.stack(r, axis=0) for r in phases]  # each [s, C, H, W]
    grid = jnp.stack(rows, axis=0)  # [s, s, C, H, W]
    c, h, w = grid.shape[2], grid.shape[3], grid.shape[4]
    # [C, H, s_y, W, s_x] -> [C, H*s, W*s]
    out = jnp.transpose(grid, (2, 3, 0, 4, 1))
    return out.reshape(c, h * s, w * s)


@partial(jax.jit, static_argnames=("stride", "padding"))
def tdc_deconv(x: jax.Array, w: jax.Array, stride: int, padding: int) -> jax.Array:
    """DeConv of x[C_in,H,W] with w[C_in,C_out,K,K] via the TDC method.

    Bit-for-bit the same function as the standard DeConv (Fig. 2); the
    S^2 sub-convolutions have no output dependencies."""
    s = stride
    kc = tdc_kc(w.shape[2], s)
    g, d0 = decompose(w, s, padding)
    phases = []
    for py in range(s):
        row = []
        for px in range(s):
            xp = phase_pad(x, d0[py, px], kc)
            row.append(correlate_valid(xp, g[py, px]))
        phases.append(row)
    return interleave_phases(phases, s)


@partial(jax.jit, static_argnames=("stride", "padding"))
def zero_padded_deconv(x: jax.Array, w: jax.Array, stride: int, padding: int) -> jax.Array:
    """Baseline: fractionally-strided conv (input dilation + flipped filter).

    Same function again; this is the computation the zero-padded baseline
    accelerator performs (multiplying inserted zeros)."""
    c_in, c_out, k, _ = w.shape
    s, p = stride, padding
    pad = k - 1 - p
    lhs = x[None]
    rhs = jnp.transpose(w[:, :, ::-1, ::-1], (1, 0, 2, 3))  # OIHW, flipped
    out = jax.lax.conv_general_dilated(
        lhs, rhs,
        window_strides=(1, 1),
        padding=((pad, pad + s - 1), (pad, pad + s - 1)),
        lhs_dilation=(s, s),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    h, wdt = x.shape[1], x.shape[2]
    return out[0, :, : s * h, : s * wdt]
