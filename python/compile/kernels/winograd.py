"""Winograd F(2x2, 3x3) minimal filtering in JAX + the Pallas compute engine.

The Pallas kernel here is the paper's accelerating-engine hot-spot (Fig. 5/7):
element-wise multiply-accumulate in the Winograd domain over the reordered
``n^2 x N`` filter/tile layout, with *vector-level sparsity*: whole Winograd
positions whose transformed weights are structurally zero are skipped.  The
skip list is static (it depends only on the sub-filter support, Fig. 3), so
it compiles to a gather of non-zero positions -- no dynamic sparsity.

Hardware adaptation (FPGA -> TPU-style):
  * the FPGA's T_m x T_n MAC array becomes an MXU-shaped contraction
    ``M[t, p, co] = sum_ci V[t, p, ci] * U[p, co, ci]`` batched over the
    non-zero Winograd positions p;
  * BRAM line-buffer ping-pong becomes BlockSpec pipelining over tile blocks
    (HBM -> VMEM double buffering);
  * pre-PE / post-PE transforms (B^T Z B, A^T M A) run inside the kernel on
    the VMEM-resident block.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that the rust runtime runs.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

M_TILE = 2  # m: outputs per tile per dim
R_TAPS = 3  # r: filter taps per dim
N_TILE = 4  # n = m + r - 1: input tile size per dim

BT = jnp.array(
    [
        [1.0, 0.0, -1.0, 0.0],
        [0.0, 1.0, 1.0, 0.0],
        [0.0, -1.0, 1.0, 0.0],
        [0.0, 1.0, 0.0, -1.0],
    ],
    dtype=jnp.float32,
)
G = jnp.array(
    [
        [1.0, 0.0, 0.0],
        [0.5, 0.5, 0.5],
        [0.5, -0.5, 0.5],
        [0.0, 0.0, 1.0],
    ],
    dtype=jnp.float32,
)
AT = jnp.array(
    [
        [1.0, 1.0, 1.0, 0.0],
        [0.0, 1.0, -1.0, -1.0],
    ],
    dtype=jnp.float32,
)

#: tiles per Pallas block along the tile axis (VMEM sizing knob; see
#: DESIGN.md section 7 and EXPERIMENTS.md section Perf for how this was chosen).
TILE_BLOCK = 64


def filter_transform(g: jax.Array) -> jax.Array:
    """U = G f G^T, zero-padding r<3 supports to 3x3.  g[ci,co,r,r] -> [ci,co,4,4]."""
    c_in, c_out, r, r2 = g.shape
    gp = jnp.zeros((c_in, c_out, R_TAPS, R_TAPS), g.dtype)
    gp = gp.at[:, :, :r, :r2].set(g)
    gm = G.astype(g.dtype)
    return jnp.einsum("ij,cojk,lk->coil", gm, gp, gm)


def nonzero_positions(r_y: int, r_x: int) -> tuple[int, ...]:
    """Static list of non-zero Winograd positions (row-major in the 4x4)
    for a sub-filter with r_y x r_x real taps.  len is 16/12/9 for
    Case 1/2/3 (Fig. 6)."""
    pos = []
    for i in range(N_TILE):
        if i == 3 and r_y < 3:
            continue
        for j in range(N_TILE):
            if j == 3 and r_x < 3:
                continue
            pos.append(i * N_TILE + j)
    return tuple(pos)


def sparsity_case(r_y: int, r_x: int) -> int:
    """Paper Fig. 6 case number: 1 (dense), 2 (n zero rows), 3 (2n-1)."""
    nz = len(nonzero_positions(r_y, r_x))
    return {16: 1, 12: 2, 9: 3}[nz]


def extract_tiles(x: jax.Array, tiles_h: int, tiles_w: int) -> jax.Array:
    """x[C, H, W] -> overlapping 4x4 input tiles [T, C, 4, 4] with stride m=2.

    The pre-PE window-selection step: H must be >= 2*tiles_h + 2.

    Gather formulation. An alternative with n^2 = 16 strided slices (one
    per within-tile offset) was measured and REJECTED: 330 µs vs 233 µs
    per layer exec on the CPU PJRT backend (EXPERIMENTS.md §Perf iter. 5)
    — XLA fuses the two gathers better than 16 slices + stack."""
    c = x.shape[0]
    idx_h = (2 * np.arange(tiles_h))[:, None] + np.arange(N_TILE)[None, :]
    idx_w = (2 * np.arange(tiles_w))[:, None] + np.arange(N_TILE)[None, :]
    # gather rows then cols
    t = x[:, idx_h, :]  # [C, th, 4, W]
    t = t[:, :, :, idx_w]  # [C, th, 4, tw, 4]
    t = jnp.transpose(t, (1, 3, 0, 2, 4))  # [th, tw, C, 4, 4]
    return t.reshape(tiles_h * tiles_w, c, N_TILE, N_TILE)


def _bt_lines(z4):
    """1D B^T transform along a leading list of 4 arrays (paper eq. 3):
    [z0-z2, z1+z2, z2-z1, z1-z3].  Pure adds -- like the FPGA pre-PE."""
    z0, z1, z2, z3 = z4
    return [z0 - z2, z1 + z2, z2 - z1, z1 - z3]


def _at_lines(m4):
    """1D A^T inverse transform: [m0+m1+m2, m1-m2-m3] with None == 0
    (structurally-zero Winograd positions are simply never summed --
    the paper's sparse inverse transform in the post-PE)."""
    m0, m1, m2, m3 = m4

    def add(a, b):
        if a is None:
            return b
        if b is None:
            return a
        return a + b

    def sub(a, b):
        if b is None:
            return a
        if a is None:
            return -b
        return a - b

    return [add(add(m0, m1), m2), sub(sub(m1, m2), m3)]


def _engine_kernel(nz: tuple[int, ...]):
    """Build the Pallas kernel body for a static non-zero position list.

    All transforms are hand-unrolled adds (Pallas kernels may not capture
    constant arrays, and the FPGA pre/post-PEs are adder trees, not
    matmuls); the sparsity gather/scatter is static python indexing, so it
    lowers to plain slices -- no dynamic sparsity on the hot path."""

    def kernel(z_ref, u_ref, y_ref):
        # z_ref: [TB, C_in, 4, 4] input tiles (VMEM block)
        # u_ref: [P_nz, C_out, C_in] transformed filters, zero rows gathered out
        # y_ref: [TB, C_out, 2, 2] spatial-domain output tiles
        z = z_ref[...]
        u = u_ref[...]
        # pre-PE: V = B^T Z B via explicit adder trees
        rows = _bt_lines([z[:, :, i, :] for i in range(N_TILE)])  # each [TB,C,4]
        v = [[None] * N_TILE for _ in range(N_TILE)]
        for i in range(N_TILE):
            cols = _bt_lines([rows[i][:, :, j] for j in range(N_TILE)])
            for j in range(N_TILE):
                v[i][j] = cols[j]  # [TB, C_in]
        # com-PE: per-position contraction over input channels (MXU-shaped),
        # only for the statically non-zero Winograd positions
        m = [[None] * N_TILE for _ in range(N_TILE)]
        for idx, p in enumerate(nz):
            i, j = p // N_TILE, p % N_TILE
            m[i][j] = jnp.einsum("tc,oc->to", v[i][j], u[idx])  # [TB, C_out]
        # post-PE: sparse inverse transform Y = A^T M A (zero positions are
        # skipped entirely -- fewer adds, exactly the paper's latency saving)
        half = [_at_lines([m[i][j] for i in range(N_TILE)]) for j in range(N_TILE)]
        for a in range(M_TILE):
            out_row = _at_lines([half[j][a] for j in range(N_TILE)])
            for b in range(M_TILE):
                y_ref[:, :, a, b] = out_row[b]

    return kernel


@partial(jax.jit, static_argnames=("nz", "tile_block"))
def winograd_engine(z_tiles: jax.Array, u_nz: jax.Array, nz: tuple[int, ...],
                    tile_block: int = TILE_BLOCK) -> jax.Array:
    """Run the Pallas accelerating engine over extracted input tiles.

    z_tiles: [T, C_in, 4, 4];  u_nz: [P_nz, C_out, C_in] (pre-gathered);
    returns [T, C_out, 2, 2].  T is padded to a multiple of tile_block."""
    t, c_in = z_tiles.shape[0], z_tiles.shape[1]
    c_out = u_nz.shape[1]
    tb = min(tile_block, t) if t > 0 else 1
    t_pad = (t + tb - 1) // tb * tb
    z = jnp.pad(z_tiles, ((0, t_pad - t), (0, 0), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _engine_kernel(nz),
        out_shape=jax.ShapeDtypeStruct((t_pad, c_out, M_TILE, M_TILE), z_tiles.dtype),
        grid=(t_pad // tb,),
        in_specs=[
            pl.BlockSpec((tb, c_in, N_TILE, N_TILE), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((len(nz), c_out, c_in), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, c_out, M_TILE, M_TILE), lambda i: (i, 0, 0, 0)),
        interpret=True,
    )(z, u_nz)
    return out[:t]


def tiles_to_map(y_tiles: jax.Array, tiles_h: int, tiles_w: int) -> jax.Array:
    """[T, C, 2, 2] output tiles -> feature map [C, 2*tiles_h, 2*tiles_w]."""
    t, c = y_tiles.shape[0], y_tiles.shape[1]
    y = y_tiles.reshape(tiles_h, tiles_w, c, M_TILE, M_TILE)
    y = jnp.transpose(y, (2, 0, 3, 1, 4))
    return y.reshape(c, tiles_h * M_TILE, tiles_w * M_TILE)


@partial(jax.jit, static_argnames=("r_y", "r_x"))
def winograd_conv2d(x: jax.Array, g: jax.Array, r_y: int | None = None,
                    r_x: int | None = None) -> jax.Array:
    """Valid correlation of x[C_in,H,W] with g[C_in,C_out,r,r] (r<=3) via
    F(2x2,3x3) using the Pallas engine.  (H-2, W-2) must be even.

    r_y/r_x override the *structural* support (defaults: g's shape) so
    callers can force the dense Case-1 path for ablation."""
    c_in, h, w = x.shape
    r_y = g.shape[2] if r_y is None else r_y
    r_x = g.shape[3] if r_x is None else r_x
    ho, wo = h - (R_TAPS - 1), w - (R_TAPS - 1)
    assert ho % M_TILE == 0 and wo % M_TILE == 0
    tiles_h, tiles_w = ho // M_TILE, wo // M_TILE
    u = filter_transform(g)  # [ci, co, 4, 4]
    nz = nonzero_positions(r_y, r_x)
    u_flat = u.reshape(c_in, g.shape[1], N_TILE * N_TILE)
    u_nz = jnp.transpose(u_flat, (2, 1, 0))[jnp.array(nz)]  # [P, co, ci]
    z = extract_tiles(x, tiles_h, tiles_w)
    y_tiles = winograd_engine(z, u_nz, nz)
    return tiles_to_map(y_tiles, tiles_h, tiles_w)
