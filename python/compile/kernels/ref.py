"""Pure-numpy correctness oracles for the Winograd-DeConv kernel stack.

Everything here is deliberately slow and obviously correct: nested loops,
no vectorisation tricks.  These oracles are the ground truth that the JAX /
Pallas implementations (tdc.py, winograd.py, winograd_deconv.py) are tested
against, and they mirror the conventions used by the rust substrates
(rust/src/tdc, rust/src/winograd).

Conventions
-----------
* Single image, channel-first: ``x`` has shape ``[C_in, H, W]``.
* DeConv (transposed-conv) filters use the conv-transpose layout
  ``w[C_in, C_out, K, K]``.
* DeConv semantics (the paper's "standard DeConv", Fig. 1a/2a)::

      y[co, oy, ox] = sum_{ci, ky, kx} x[ci, iy, ix] * w[ci, co, ky, kx]
        where  S*iy = oy + P - ky   and   S*ix = ox + P - kx,

  with the output cropped to ``[C_out, S*H, S*W]``.  For the paper's layer
  configs -- (K=5, S=2, P=2), (K=4, S=2, P=1), (K=3, S=1, P=1) -- this is
  torch's ``ConvTranspose2d(stride=S, padding=P, output_padding=S-K+2P)``
  and keeps ``H_O = S * H_I`` as the paper assumes throughout.
"""

from __future__ import annotations

import math

import numpy as np

# ---------------------------------------------------------------------------
# Winograd F(2x2, 3x3) transform matrices (paper eq. 3).
# ---------------------------------------------------------------------------

# BT: 4x4 input transform, G: 4x3 filter transform, AT: 2x4 inverse transform.
BT = np.array(
    [
        [1.0, 0.0, -1.0, 0.0],
        [0.0, 1.0, 1.0, 0.0],
        [0.0, -1.0, 1.0, 0.0],
        [0.0, 1.0, 0.0, -1.0],
    ]
)
G = np.array(
    [
        [1.0, 0.0, 0.0],
        [0.5, 0.5, 0.5],
        [0.5, -0.5, 0.5],
        [0.0, 0.0, 1.0],
    ]
)
AT = np.array(
    [
        [1.0, 1.0, 1.0, 0.0],
        [0.0, 1.0, -1.0, -1.0],
    ]
)

#: Winograd tile parameters for the uniform F(2x2, 3x3) the paper uses.
M_TILE = 2  # outputs per tile per dim (m)
R_TAPS = 3  # filter taps per dim (r)
N_TILE = M_TILE + R_TAPS - 1  # input tile size per dim (n = 4)


def deconv_output_padding(k: int, s: int, p: int) -> int:
    """output_padding that keeps H_O = S*H_I (torch convention)."""
    return s - k + 2 * p


def default_padding(k: int, s: int) -> int:
    """The paper's layer configs: P=2 for K=5/S=2, P=1 for K=4/S=2 and K=3/S=1."""
    return (k - s + 1) // 2


def deconv_naive(x: np.ndarray, w: np.ndarray, stride: int, padding: int) -> np.ndarray:
    """Standard DeConv by direct scatter-add (the paper's Fig. 2a)."""
    c_in, h, wdt = x.shape
    c_in2, c_out, k, k2 = w.shape
    assert c_in == c_in2 and k == k2
    s, p = stride, padding
    ho, wo = s * h, s * wdt
    y = np.zeros((c_out, ho, wo), dtype=np.float64)
    for ci in range(c_in):
        for iy in range(h):
            for ix in range(wdt):
                for ky in range(k):
                    for kx in range(k):
                        oy = s * iy + ky - p
                        ox = s * ix + kx - p
                        if 0 <= oy < ho and 0 <= ox < wo:
                            y[:, oy, ox] += x[ci, iy, ix] * w[ci, :, ky, kx]
    return y


def zero_padded_deconv(x: np.ndarray, w: np.ndarray, stride: int, padding: int) -> np.ndarray:
    """The zero-padded DeConv baseline (Fig. 1b): dilate the input with S-1
    zeros, border-pad by K-1-P, then run an ordinary (flipped-filter) Conv.

    Numerically identical to :func:`deconv_naive`; kept separate because the
    baseline *accelerator* models this computation (it multiplies the padded
    zeros unless it adds skip logic)."""
    c_in, h, wdt = x.shape
    _, c_out, k, _ = w.shape
    s, p = stride, padding
    pad = k - 1 - p
    assert pad >= 0, "padding must satisfy P <= K-1"
    hd = s * (h - 1) + 1 + 2 * pad
    wd = s * (wdt - 1) + 1 + 2 * pad
    xd = np.zeros((c_in, hd, wd), dtype=np.float64)
    xd[:, pad : pad + s * (h - 1) + 1 : s, pad : pad + s * (wdt - 1) + 1 : s] = x
    ho, wo = s * h, s * wdt
    y = np.zeros((c_out, ho, wo), dtype=np.float64)
    wf = w[:, :, ::-1, ::-1]  # flip: transposed conv == conv with flipped filter
    for co in range(c_out):
        for oy in range(ho):
            for ox in range(wo):
                acc = 0.0
                for ci in range(c_in):
                    for ky in range(k):
                        for kx in range(k):
                            iy, ix = oy + ky, ox + kx
                            if iy < hd and ix < wd:
                                acc += xd[ci, iy, ix] * wf[ci, co, ky, kx]
                y[co, oy, ox] = acc
    return y


# ---------------------------------------------------------------------------
# TDC: DeConv -> S^2 Conv decomposition (paper Fig. 1c / 2b, refs [14-16]).
# ---------------------------------------------------------------------------


def tdc_kc(k: int, s: int) -> int:
    """Width of the converted Conv kernel, K_C = ceil(K_D / S) (Table I)."""
    return math.ceil(k / s)


def tdc_phase_taps_1d(k: int, s: int, p: int, phase: int):
    """1D sub-filter tap indices and input offset for one output phase.

    Output sample ``y[S*i + phase]`` equals a *correlation* of the input with
    the phase's sub-filter::

        y[S*i + phase] = sum_u  g[u] * x[i + u + d0]

    Returns ``(taps, d0)`` where ``taps[u]`` indexes the *flipped* 1D kernel
    (``wf[t] = w[K-1-t]``) for tap ``u`` (or -1 for an implicit zero-pad
    tap), and ``d0`` is the input offset.  ``len(taps) == K_C`` always;
    shorter phases are zero-padded at the tail -- these are the "many zeros
    in the S^2 Conv filters" the paper exploits."""
    pad = k - 1 - p
    assert pad >= 0
    t0 = (pad - phase) % s
    kc = tdc_kc(k, s)
    n_real = max(0, math.ceil((k - t0) / s))
    assert n_real <= kc
    assert (phase + t0 - pad) % s == 0
    d0 = (phase + t0 - pad) // s
    assert -(kc - 1) <= d0 <= 0, (
        f"TDC offset {d0} out of range for K={k} S={s} P={p}; "
        "padding too small for a uniform-K_C decomposition"
    )
    taps = [s * u + t0 if u < n_real else -1 for u in range(kc)]
    return taps, d0


def tdc_decompose(w: np.ndarray, stride: int, padding: int):
    """Decompose DeConv filters into S^2 Conv sub-filter banks.

    Returns ``(g, d0)`` with ``g[S, S, C_in, C_out, K_C, K_C]`` (correlation
    filters) and ``d0[S, S, 2]`` input offsets per phase."""
    c_in, c_out, k, _ = w.shape
    s = stride
    kc = tdc_kc(k, s)
    wf = w[:, :, ::-1, ::-1]
    g = np.zeros((s, s, c_in, c_out, kc, kc), dtype=np.float64)
    d0 = np.zeros((s, s, 2), dtype=np.int64)
    for py in range(s):
        taps_y, d0y = tdc_phase_taps_1d(k, s, padding, py)
        for px in range(s):
            taps_x, d0x = tdc_phase_taps_1d(k, s, padding, px)
            d0[py, px] = (d0y, d0x)
            for uy, ty in enumerate(taps_y):
                if ty < 0:
                    continue
                for ux, tx in enumerate(taps_x):
                    if tx < 0:
                        continue
                    g[py, px, :, :, uy, ux] = wf[:, :, ty, tx]
    return g, d0


def correlate_valid(x: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Multi-channel valid correlation: x[C_in,H,W] * g[C_in,C_out,K,K]."""
    c_in, h, wdt = x.shape
    _, c_out, k, k2 = g.shape
    ho, wo = h - k + 1, wdt - k2 + 1
    y = np.zeros((c_out, ho, wo), dtype=np.float64)
    for co in range(c_out):
        for oy in range(ho):
            for ox in range(wo):
                acc = 0.0
                for ci in range(c_in):
                    for ky in range(k):
                        for kx in range(k2):
                            acc += x[ci, oy + ky, ox + kx] * g[ci, co, ky, kx]
                y[co, oy, ox] = acc
    return y


def tdc_deconv(x: np.ndarray, w: np.ndarray, stride: int, padding: int) -> np.ndarray:
    """DeConv via the TDC method: S^2 ordinary convolutions, outputs
    interleaved into the S x S phase grid.  Identical result to
    :func:`deconv_naive` (the paper's Fig. 2 equivalence)."""
    c_in, h, wdt = x.shape
    _, c_out, k, _ = w.shape
    s = stride
    kc = tdc_kc(k, s)
    g, d0 = tdc_decompose(w, stride, padding)
    y = np.zeros((c_out, s * h, s * wdt), dtype=np.float64)
    for py in range(s):
        for px in range(s):
            d0y, d0x = int(d0[py, px, 0]), int(d0[py, px, 1])
            ly, ry = -d0y, kc - 1 + d0y
            lx, rx = -d0x, kc - 1 + d0x
            xp = np.zeros((c_in, h + ly + ry, wdt + lx + rx), dtype=np.float64)
            xp[:, ly : ly + h, lx : lx + wdt] = x
            yp = correlate_valid(xp, g[py, px])
            y[:, py::s, px::s] = yp
    return y


# ---------------------------------------------------------------------------
# Winograd F(2x2, 3x3) reference (dense, paper eq. 4) + sparsity analysis.
# ---------------------------------------------------------------------------


def winograd_filter_transform(g: np.ndarray) -> np.ndarray:
    """U = G f G^T for a bank g[C_in, C_out, r, r] with r <= 3 (zero-padded
    to 3x3 first, as the paper does for K_C = 2).  Returns [C_in,C_out,4,4]."""
    c_in, c_out, r, r2 = g.shape
    assert r <= R_TAPS and r2 <= R_TAPS
    gp = np.zeros((c_in, c_out, R_TAPS, R_TAPS), dtype=np.float64)
    gp[:, :, :r, :r2] = g
    return np.einsum("ij,cojk,lk->coil", G, gp, G)


def winograd_input_transform(z: np.ndarray) -> np.ndarray:
    """V = B^T Z B for tiles z[..., 4, 4]."""
    return np.einsum("ij,...jk,lk->...il", BT, z, BT)


def winograd_inverse_transform(mm: np.ndarray) -> np.ndarray:
    """Y = A^T M A for tiles m[..., 4, 4] -> [..., 2, 2]."""
    return np.einsum("ij,...jk,lk->...il", AT, mm, AT)


def winograd_conv2d(x: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Valid correlation via F(2x2,3x3): x[C_in,H,W], g[C_in,C_out,r,r] with
    r<=3 zero-padded to 3.  Output [C_out, H-2, W-2] (3-tap valid size);
    H-2 and W-2 must be even (callers tile-align)."""
    c_in, h, wdt = x.shape
    _, c_out, _, _ = g.shape
    ho, wo = h - (R_TAPS - 1), wdt - (R_TAPS - 1)
    assert ho % M_TILE == 0 and wo % M_TILE == 0, "tile-align inputs first"
    u = winograd_filter_transform(g)  # [ci, co, 4, 4]
    y = np.zeros((c_out, ho, wo), dtype=np.float64)
    for ty in range(ho // M_TILE):
        for tx in range(wo // M_TILE):
            z = x[:, 2 * ty : 2 * ty + N_TILE, 2 * tx : 2 * tx + N_TILE]
            v = winograd_input_transform(z)  # [ci, 4, 4]
            mm = np.einsum("coij,cij->oij", u, v)  # channel sum in Winograd domain
            y[:, 2 * ty : 2 * ty + 2, 2 * tx : 2 * tx + 2] = winograd_inverse_transform(mm)
    return y


def sparsity_pattern(r_y: int, r_x: int) -> np.ndarray:
    """Structural non-zero mask (4x4 bool) of G f G^T for a filter whose real
    support is r_y x r_x taps (top-left), zero-padded to 3x3.

    G row 3 is [0,0,1]: it only touches tap index 2, so a 2-tap dimension
    zeroes the 4th row/column of the transformed filter.  r=3 in both dims
    -> all 16 non-zero (Case 1); one dim with r=2 -> one zero line, 12
    non-zero (Case 2); both dims r=2 -> 9 non-zero (Case 3).  Fig. 3/6."""
    assert 1 <= r_y <= 3 and 1 <= r_x <= 3
    mask_y = np.array([True, True, True, r_y >= 3])
    mask_x = np.array([True, True, True, r_x >= 3])
    return np.outer(mask_y, mask_x)


def winograd_tdc_deconv(x: np.ndarray, w: np.ndarray, stride: int, padding: int) -> np.ndarray:
    """The paper's full fast algorithm: TDC -> zero-pad sub-filters to 3x3 ->
    F(2x2,3x3) Winograd per phase -> interleave phases into mS x mS output
    blocks.  Ground-truth oracle for the fused Pallas kernel and for the rust
    functional simulator."""
    c_in, h, wdt = x.shape
    _, c_out, k, _ = w.shape
    s = stride
    g, d0 = tdc_decompose(w, stride, padding)
    y = np.zeros((c_out, s * h, s * wdt), dtype=np.float64)
    # tile-align: each phase produces an h x w map; pad input so Winograd
    # produces ceil(h/m)*m rows, then crop.
    ho_t = ((h + M_TILE - 1) // M_TILE) * M_TILE
    wo_t = ((wdt + M_TILE - 1) // M_TILE) * M_TILE
    for py in range(s):
        for px in range(s):
            d0y, d0x = int(d0[py, px, 0]), int(d0[py, px, 1])
            ly, lx = -d0y, -d0x
            ry = (ho_t + R_TAPS - 1) - h - ly
            rx = (wo_t + R_TAPS - 1) - wdt - lx
            xp = np.zeros((c_in, h + ly + ry, wdt + lx + rx), dtype=np.float64)
            xp[:, ly : ly + h, lx : lx + wdt] = x
            yp = winograd_conv2d(xp, g[py, px])[:, :h, :wdt]
            y[:, py::s, px::s] = yp
    return y


# ---------------------------------------------------------------------------
# Multiplication-count models (Fig. 4) -- mirrored by rust gan::workload.
# ---------------------------------------------------------------------------


def mults_zero_padded(m_out: int, n_in: int, h_i: int, w_i: int, k: int, s: int) -> int:
    """Zero-padded DeConv multiplications: full conv over the up-scaled map."""
    return m_out * n_in * (s * h_i) * (s * w_i) * k * k


def mults_tdc(m_out: int, n_in: int, h_i: int, w_i: int, k: int, s: int) -> int:
    """TDC DeConv multiplications: S^2 convs with K_C^2 taps on the input map."""
    kc = tdc_kc(k, s)
    return s * s * m_out * n_in * h_i * w_i * kc * kc


def winograd_nonzero_count(k: int, s: int, p: int) -> int:
    """C(K_C): total non-zero Winograd-domain weights across the S^2
    sub-filters for one (c_in, c_out) pair and one m x m tile.  49 for
    K_C=3 (K=5,S=2), 36 for K_C=2 (K=4,S=2), 16 for K=3,S=1 (eq. 5)."""
    total = 0
    for py in range(s):
        taps_y, _ = tdc_phase_taps_1d(k, s, p, py)
        ry = sum(1 for t in taps_y if t >= 0)
        for px in range(s):
            taps_x, _ = tdc_phase_taps_1d(k, s, p, px)
            rx = sum(1 for t in taps_x if t >= 0)
            total += int(sparsity_pattern(ry, rx).sum())
    return total


def mults_winograd(
    m_out: int, n_in: int, h_i: int, w_i: int, k: int, s: int, p: int
) -> int:
    """Winograd DeConv multiplications with vector-level zero skipping."""
    tiles = math.ceil(h_i / M_TILE) * math.ceil(w_i / M_TILE)
    return m_out * n_in * tiles * winograd_nonzero_count(k, s, p)


# ---------------------------------------------------------------------------
# Layer hand-off activations -- mirrored by rust gan::zoo::Activation.
# ---------------------------------------------------------------------------

#: activation names shared with the rust zoo ("linear" is the identity;
#: ``model.py``'s LayerCfg spells it "none" — both are accepted below)
ACTIVATIONS = ("linear", "relu", "lrelu", "tanh")


def apply_activation(x: np.ndarray, kind: str) -> np.ndarray:
    """The generator hand-off activation, numpy oracle form.

    Mirrors ``rust/src/gan/zoo.rs::Activation::apply_scalar`` exactly:
    ``relu`` clamps negatives to zero, ``lrelu`` multiplies them by 0.2
    (DiscoGAN's encoder), ``tanh`` is the image-space output layer, and
    ``linear`` is the identity used by single-layer plans.  ``none`` is
    accepted as an alias for the identity so ``model.py``'s ``LayerCfg.act``
    values feed straight in.
    """
    if kind in ("linear", "none"):
        return x
    if kind == "relu":
        return np.where(x < 0, np.zeros_like(x), x)
    if kind == "lrelu":
        return np.where(x < 0, x * 0.2, x)
    if kind == "tanh":
        return np.tanh(x)
    raise ValueError(f"unknown activation {kind!r}")
