"""The paper's fused fast algorithm: Winograd DeConv (TDC + F(2x2,3x3) +
vector-level sparsity), end to end.

Pipeline (Fig. 3 / Fig. 5):
  1. TDC-decompose the DeConv filter into S^2 sub-filter banks (trace time).
  2. Transform each bank to the Winograd domain (G f G^T, trace time) and
     gather the statically non-zero positions per sparsity case.
  3. Per phase: extract overlapping 4x4 input tiles, run the Pallas
     accelerating engine (winograd.winograd_engine) over the reordered
     n^2 x N layout, inverse-transform inside the kernel.
  4. Interleave the S x S phase outputs into mS x mS output blocks.

The public entry point ``winograd_deconv`` computes exactly the same
function as ``ref.deconv_naive`` (tested in python/tests/).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import tdc as tdc_mod
from . import winograd as wg


def phase_plan(k: int, s: int, padding: int):
    """Static per-phase plan: ((r_y, r_x), (d0y, d0x)) for each (py, px)."""
    from . import ref

    plan = []
    for py in range(s):
        taps_y, d0y = ref.tdc_phase_taps_1d(k, s, padding, py)
        ry = sum(1 for t in taps_y if t >= 0)
        for px in range(s):
            taps_x, d0x = ref.tdc_phase_taps_1d(k, s, padding, px)
            rx = sum(1 for t in taps_x if t >= 0)
            plan.append(((py, px), (ry, rx), (d0y, d0x)))
    return plan


@partial(jax.jit, static_argnames=("stride", "padding", "tile_block"))
def winograd_deconv(x: jax.Array, w: jax.Array, stride: int, padding: int,
                    tile_block: int = wg.TILE_BLOCK) -> jax.Array:
    """DeConv of x[C_in,H,W] with w[C_in,C_out,K,K] via the fused
    TDC + Winograd + sparsity-skip fast algorithm (the paper's contribution).

    Output: [C_out, S*H, S*W]."""
    y = winograd_deconv_batched(x[None], w, stride, padding, tile_block)
    return y[0]


@partial(jax.jit, static_argnames=("stride", "padding", "tile_block"))
def winograd_deconv_batched(xb: jax.Array, w: jax.Array, stride: int, padding: int,
                            tile_block: int = wg.TILE_BLOCK) -> jax.Array:
    """Batched DeConv of xb[B,C_in,H,W]: the batch dimension is folded into
    the Winograd *tile* dimension, so the whole batch runs through ONE
    Pallas engine invocation per phase (no vmap of pallas_call — measured
    3.4x faster at B=8 on the CPU PJRT backend, see EXPERIMENTS.md §Perf
    iter. 7). This mirrors the hardware: a bigger batch is simply more
    tiles streaming through the same com-PE array.

    Output: [B, C_out, S*H, S*W]."""
    bsz, c_in, h, wdt = xb.shape
    _, c_out, k, _ = w.shape
    s = stride
    g, d0 = tdc_mod.decompose(w, s, padding)

    # tile-aligned phase output size
    ho_t = (h + wg.M_TILE - 1) // wg.M_TILE * wg.M_TILE
    wo_t = (wdt + wg.M_TILE - 1) // wg.M_TILE * wg.M_TILE
    tiles_h, tiles_w = ho_t // wg.M_TILE, wo_t // wg.M_TILE
    n_tiles = tiles_h * tiles_w

    phases = [[None] * s for _ in range(s)]
    for (py, px), (ry, rx), (d0y, d0x) in phase_plan(k, s, padding):
        # pad so the 3x3-padded winograd filter sees (ho_t+2, wo_t+2) inputs
        ly, lx = -d0y, -d0x
        ry_pad = (ho_t + wg.R_TAPS - 1) - h - ly
        rx_pad = (wo_t + wg.R_TAPS - 1) - wdt - lx
        xp = jnp.pad(xb, ((0, 0), (0, 0), (ly, ry_pad), (lx, rx_pad)))
        # winograd-domain filters for this phase, zero positions gathered out
        u = wg.filter_transform(g[py, px])  # [ci, co, 4, 4]
        nz = wg.nonzero_positions(ry, rx)
        u_flat = u.reshape(c_in, c_out, wg.N_TILE * wg.N_TILE)
        u_nz = jnp.transpose(u_flat, (2, 1, 0))[jnp.array(nz)]
        # per-sample tile extraction (cheap gathers), then fold B into T
        z = jax.vmap(lambda xi: wg.extract_tiles(xi, tiles_h, tiles_w))(xp)
        z = z.reshape(bsz * n_tiles, c_in, wg.N_TILE, wg.N_TILE)
        y_tiles = wg.winograd_engine(z, u_nz, nz, tile_block=tile_block)
        y_tiles = y_tiles.reshape(bsz, n_tiles, c_out, wg.M_TILE, wg.M_TILE)
        yp = jax.vmap(lambda t: wg.tiles_to_map(t, tiles_h, tiles_w))(y_tiles)
        phases[py][px] = yp[:, :, :h, :wdt]

    # interleave phases with a leading batch axis
    rows = [jnp.stack(r, axis=0) for r in phases]  # [s, B, C, H, W]
    grid = jnp.stack(rows, axis=0)  # [s, s, B, C, H, W]
    out = jnp.transpose(grid, (2, 3, 4, 0, 5, 1))  # [B, C, H, s, W, s]
    return out.reshape(bsz, c_out, h * s, wdt * s)
