"""L1 kernels: Pallas Winograd-DeConv engine + pure oracles.

Modules:
  ref              -- numpy oracles (ground truth)
  tdc              -- JAX TDC decomposition + baseline deconvs
  winograd         -- F(2x2,3x3) transforms + Pallas accelerating engine
  winograd_deconv  -- the paper's fused fast algorithm
"""
