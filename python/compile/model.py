"""L2: JAX generator models for the paper's GAN zoo (Table I).

Each generator is a stack of DeConv layers executed through one of three
interchangeable compute paths:

  * ``winograd``  -- the paper's fused fast algorithm (Pallas engine,
                     kernels/winograd_deconv.py); the system's default.
  * ``tdc``       -- TDC-converted convs (baseline [14]).
  * ``zero_pad``  -- fractionally-strided conv (baseline [10-12]).

All three compute the same function; artifacts are AOT-lowered from here by
``aot.py`` and executed by the rust runtime -- python never runs at serving
time.

Geometry follows Table I plus the original papers' channel configs (see
DESIGN.md section 5).  ``scale="small"`` divides channel widths by 8 so that the
1-core CPU box can execute full generators through the interpret-mode
Winograd path in reasonable time; the analytic benches in rust use the
``paper`` scale.  Weights are seeded-random: the accelerator's behaviour is
weight-value-independent (the exploited sparsity is structural).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref, tdc as tdc_mod, winograd_deconv as wd

METHODS = ("winograd", "tdc", "zero_pad")


@dataclasses.dataclass(frozen=True)
class LayerCfg:
    """One generator layer.  kind: 'deconv' | 'conv'."""

    kind: str
    c_in: int
    c_out: int
    k: int
    s: int
    p: int
    h_in: int
    w_in: int
    act: str  # 'relu' | 'lrelu' | 'tanh' | 'none'
    norm: bool = True

    @property
    def h_out(self) -> int:
        if self.kind == "deconv":
            return self.s * self.h_in
        return self.h_in // self.s

    @property
    def w_out(self) -> int:
        if self.kind == "deconv":
            return self.s * self.w_in
        return self.w_in // self.s

    @property
    def kc(self) -> int:
        """TDC-converted kernel width (Table I's K_C)."""
        return ref.tdc_kc(self.k, self.s) if self.kind == "deconv" else self.k


@dataclasses.dataclass(frozen=True)
class GanCfg:
    """A generative network: optional latent projection + layer stack."""

    name: str
    layers: tuple
    z_dim: int | None  # None => image-to-image (input is [3, 64, 64])
    seed: int = 7

    @property
    def input_shape(self) -> tuple:
        if self.z_dim is not None:
            return (self.z_dim,)
        l0 = self.layers[0]
        return (l0.c_in, l0.h_in, l0.w_in)

    @property
    def output_shape(self) -> tuple:
        ll = self.layers[-1]
        return (ll.c_out, ll.h_out, ll.w_out)


def _deconv_stack(channels, k, s, h0, name_final_act="tanh"):
    """Chain of DeConv layers doubling spatial dims: channels[i]->channels[i+1]."""
    p = ref.default_padding(k, s)
    layers = []
    h = h0
    for i in range(len(channels) - 1):
        last = i == len(channels) - 2
        layers.append(
            LayerCfg(
                kind="deconv", c_in=channels[i], c_out=channels[i + 1],
                k=k, s=s, p=p, h_in=h, w_in=h,
                act=name_final_act if last else "relu", norm=not last,
            )
        )
        h *= s
    return layers, h


def zoo(scale: str = "paper") -> dict:
    """The four GANs of Table I.  scale in {'paper', 'small'}."""
    assert scale in ("paper", "small")
    d = 8 if scale == "small" else 1

    def ch(c):
        return max(c // d, 4) if c > 3 else c

    models: dict[str, GanCfg] = {}

    # DCGAN [4]: 4 DeConv, K_D=5, S=2.  z -> 4x4x1024 -> ... -> 64x64x3.
    layers, _ = _deconv_stack([ch(1024), ch(512), ch(256), ch(128), 3], k=5, s=2, h0=4)
    models["dcgan"] = GanCfg("dcgan", tuple(layers), z_dim=100 if d == 1 else 32)

    # ArtGAN [5]: 4 DeConv K_D=4 S=2 + 1 DeConv K_D=3 S=1.
    layers, h = _deconv_stack([ch(512), ch(256), ch(128), ch(64), ch(64)], k=4, s=2, h0=4,
                              name_final_act="relu")
    layers[-1] = dataclasses.replace(layers[-1], norm=True)
    layers.append(
        LayerCfg(kind="deconv", c_in=ch(64), c_out=3, k=3, s=1,
                 p=ref.default_padding(3, 1), h_in=h, w_in=h, act="tanh", norm=False)
    )
    models["artgan"] = GanCfg("artgan", tuple(layers), z_dim=100 if d == 1 else 32)

    # DiscoGAN [6]: 5 Conv encoder + 4 DeConv decoder (image-to-image).
    enc_ch = [3, ch(64), ch(128), ch(256), ch(512)]
    enc = []
    h = 64
    for i in range(4):
        enc.append(LayerCfg(kind="conv", c_in=enc_ch[i], c_out=enc_ch[i + 1],
                            k=4, s=2, p=1, h_in=h, w_in=h, act="lrelu", norm=i > 0))
        h //= 2
    enc.append(LayerCfg(kind="conv", c_in=ch(512), c_out=ch(512), k=3, s=1, p=1,
                        h_in=h, w_in=h, act="lrelu", norm=True))
    dec, _ = _deconv_stack([ch(512), ch(256), ch(128), ch(64), 3], k=4, s=2, h0=4)
    models["discogan"] = GanCfg("discogan", tuple(enc + dec), z_dim=None)

    # GP-GAN [7]: 4 DeConv K_D=4 S=2 from a latent bottleneck.
    layers, _ = _deconv_stack([ch(512), ch(256), ch(128), ch(64), 3], k=4, s=2, h0=4)
    models["gpgan"] = GanCfg("gpgan", tuple(layers), z_dim=100 if d == 1 else 32)

    return models


# ---------------------------------------------------------------------------
# Parameters + forward pass.
# ---------------------------------------------------------------------------


def init_params(cfg: GanCfg) -> dict:
    """Seeded-random inference parameters (weights + folded-norm scale/shift)."""
    rng = np.random.default_rng(cfg.seed)
    params: dict = {"layers": []}
    if cfg.z_dim is not None:
        l0 = cfg.layers[0]
        fan = cfg.z_dim
        params["proj_w"] = jnp.asarray(
            rng.standard_normal((cfg.z_dim, l0.c_in * l0.h_in * l0.w_in)) / np.sqrt(fan),
            jnp.float32,
        )
        params["proj_b"] = jnp.zeros((l0.c_in * l0.h_in * l0.w_in,), jnp.float32)
    for lc in cfg.layers:
        fan = lc.c_in * lc.k * lc.k
        if lc.kind == "deconv":
            w = rng.standard_normal((lc.c_in, lc.c_out, lc.k, lc.k)) / np.sqrt(fan)
        else:
            w = rng.standard_normal((lc.c_out, lc.c_in, lc.k, lc.k)) / np.sqrt(fan)
        gamma = rng.uniform(0.6, 1.4, lc.c_out) if lc.norm else np.ones(lc.c_out)
        beta = rng.uniform(-0.1, 0.1, lc.c_out) if lc.norm else np.zeros(lc.c_out)
        params["layers"].append(
            {
                "w": jnp.asarray(w, jnp.float32),
                "gamma": jnp.asarray(gamma, jnp.float32),
                "beta": jnp.asarray(beta, jnp.float32),
            }
        )
    return params


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "lrelu":
        return jax.nn.leaky_relu(x, 0.2)
    if kind == "tanh":
        return jnp.tanh(x)
    return x


def _conv(x: jax.Array, w: jax.Array, s: int, p: int) -> jax.Array:
    out = jax.lax.conv_general_dilated(
        x[None], w, window_strides=(s, s), padding=((p, p), (p, p)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def deconv_layer(x: jax.Array, w: jax.Array, s: int, p: int, method: str) -> jax.Array:
    """Dispatch one DeConv through the selected compute path."""
    if method == "winograd":
        return wd.winograd_deconv(x, w, s, p)
    if method == "tdc":
        return tdc_mod.tdc_deconv(x, w, s, p)
    if method == "zero_pad":
        return tdc_mod.zero_padded_deconv(x, w, s, p)
    raise ValueError(f"unknown method {method!r}")


def forward(cfg: GanCfg, params: dict, x: jax.Array, method: str = "winograd") -> jax.Array:
    """Single-sample generator forward: x is [z_dim] or [3, 64, 64]."""
    if cfg.z_dim is not None:
        l0 = cfg.layers[0]
        h = x @ params["proj_w"] + params["proj_b"]
        h = jax.nn.relu(h).reshape(l0.c_in, l0.h_in, l0.w_in)
    else:
        h = x
    for lc, lp in zip(cfg.layers, params["layers"]):
        if lc.kind == "deconv":
            h = deconv_layer(h, lp["w"], lc.s, lc.p, method)
        else:
            h = _conv(h, lp["w"], lc.s, lc.p)
        h = h * lp["gamma"][:, None, None] + lp["beta"][:, None, None]
        h = _act(h, lc.act)
    return h


def forward_batched(cfg: GanCfg, params: dict, xb: jax.Array,
                    method: str = "winograd", tile_block: int | None = None) -> jax.Array:
    """Batched generator forward: xb is [B, z_dim] or [B, 3, 64, 64].

    The winograd path folds the batch into the Pallas engine's tile grid
    (one pallas_call per phase for the WHOLE batch) instead of vmapping the
    kernel. ``tile_block`` sizes the engine's per-grid-step block: 64 is
    the VMEM-sized structural default for real TPU lowering; AOT CPU
    artifacts use 1024 (interpret mode pays per-grid-step overhead, no
    VMEM constraint — measured 65 ms -> 18.6 ms for DCGAN-small b8, see
    EXPERIMENTS.md §Perf iter. 7). Baseline paths batch through XLA's
    native conv batch dim."""
    if cfg.z_dim is not None:
        l0 = cfg.layers[0]
        h = xb @ params["proj_w"] + params["proj_b"]
        h = jax.nn.relu(h).reshape(-1, l0.c_in, l0.h_in, l0.w_in)
    else:
        h = xb
    for lc, lp in zip(cfg.layers, params["layers"]):
        if lc.kind == "deconv":
            if method == "winograd":
                h = wd.winograd_deconv_batched(
                    h, lp["w"], lc.s, lc.p,
                    tile_block=tile_block if tile_block else 64,
                )
            else:
                h = jax.vmap(lambda hi: deconv_layer(hi, lp["w"], lc.s, lc.p, method))(h)
        else:
            h = jax.lax.conv_general_dilated(
                h, lp["w"], window_strides=(lc.s, lc.s),
                padding=((lc.p, lc.p), (lc.p, lc.p)),
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )
        h = h * lp["gamma"][None, :, None, None] + lp["beta"][None, :, None, None]
        h = _act(h, lc.act)
    return h


#: engine block size for AOT CPU artifacts (see forward_batched docstring)
AOT_TILE_BLOCK = 1024


def batched_forward(cfg: GanCfg, params: dict, method: str = "winograd",
                    tile_block: int | None = None) -> Callable:
    """Batched generator callable over a leading batch axis."""
    return partial(forward_batched, cfg, params, method=method,
                   tile_block=tile_block)
