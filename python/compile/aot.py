"""AOT compiler: lower the JAX generators to HLO *text* + golden vectors.

This is the only place python touches the pipeline: ``make artifacts`` runs
it once; afterwards the rust binary is self-contained.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``).  The HLO text parser reassigns ids and
round-trips cleanly.  Computations are lowered with ``return_tuple=True``;
the rust side unwraps with ``to_tuple1()``.

Emits into --out-dir:
  * ``<name>.hlo.txt``           one per (model, method, batch) and per
                                 single-layer op
  * ``golden/<name>.{in,out}.bin``  raw little-endian f32 tensors for the
                                 rust integration tests
  * ``manifest.json``            index of everything above with shapes

Weights are baked into the HLO as constants, so each artifact's only runtime
input is the latent/image batch.
"""

from __future__ import annotations

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref, winograd_deconv as wd

GENERATOR_BATCHES = (1, 4, 8)
LAYER_OPS = (
    # (name, c_in, c_out, k, s, h, w) -- one per Table-I kernel class
    ("deconv_k5s2", 8, 16, 5, 2, 8, 8),
    ("deconv_k4s2", 8, 16, 4, 2, 8, 8),
    ("deconv_k3s1", 8, 16, 3, 1, 8, 8),
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: baked weights must survive the text round-trip
    # (the default elides literals over ~1K elements to `constant({...})`,
    # which the parser silently reads back as zeros!)
    return comp.as_hlo_text(print_large_constants=True)


def _write_bin(path: str, arr: np.ndarray) -> None:
    np.asarray(arr, dtype="<f4").tofile(path)


def emit_generators(out_dir: str, scale: str, methods, batches) -> list[dict]:
    entries = []
    models = M.zoo(scale)
    for name, cfg in models.items():
        params = M.init_params(cfg)
        rng = np.random.default_rng(1000 + cfg.seed)
        for method in methods:
            fwd = M.batched_forward(cfg, params, method=method,
                                    tile_block=M.AOT_TILE_BLOCK)
            for b in batches:
                tag = f"{name}_{method}_b{b}" if method != "winograd" else f"{name}_b{b}"
                in_shape = (b,) + cfg.input_shape
                spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
                lowered = jax.jit(fwd).lower(spec)
                hlo = to_hlo_text(lowered)
                hlo_rel = f"{tag}.hlo.txt"
                with open(os.path.join(out_dir, hlo_rel), "w") as f:
                    f.write(hlo)
                # golden vectors
                x = rng.standard_normal(in_shape).astype(np.float32)
                if cfg.z_dim is None:
                    x = np.tanh(x)  # image-ish range
                y = np.asarray(jax.jit(fwd)(jnp.asarray(x)))
                _write_bin(os.path.join(out_dir, "golden", f"{tag}.in.bin"), x)
                _write_bin(os.path.join(out_dir, "golden", f"{tag}.out.bin"), y)
                entries.append(
                    {
                        "name": tag,
                        "kind": "generator",
                        "model": name,
                        "method": method,
                        "batch": b,
                        "hlo": hlo_rel,
                        "input_shape": list(in_shape),
                        "output_shape": [b] + list(cfg.output_shape),
                        "golden_input": f"golden/{tag}.in.bin",
                        "golden_output": f"golden/{tag}.out.bin",
                    }
                )
                print(f"  wrote {tag}: in={list(in_shape)} out={[b] + list(cfg.output_shape)}")
    return entries


def emit_layer_ops(out_dir: str) -> list[dict]:
    """Single DeConv layers (winograd path), for quickstart + runtime tests."""
    entries = []
    rng = np.random.default_rng(42)
    for name, c_in, c_out, k, s, h, w_sp in LAYER_OPS:
        p = ref.default_padding(k, s)
        w = (rng.standard_normal((c_in, c_out, k, k)) / np.sqrt(c_in * k * k)).astype(
            np.float32
        )
        fn = partial(wd.winograd_deconv, w=jnp.asarray(w), stride=s, padding=p)
        spec = jax.ShapeDtypeStruct((c_in, h, w_sp), jnp.float32)
        lowered = jax.jit(lambda x: fn(x)).lower(spec)
        hlo_rel = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, hlo_rel), "w") as f:
            f.write(to_hlo_text(lowered))
        x = rng.standard_normal((c_in, h, w_sp)).astype(np.float32)
        y = np.asarray(jax.jit(lambda x: fn(x))(jnp.asarray(x)))
        _write_bin(os.path.join(out_dir, "golden", f"{name}.in.bin"), x)
        _write_bin(os.path.join(out_dir, "golden", f"{name}.out.bin"), y)
        entries.append(
            {
                "name": name,
                "kind": "layer",
                "model": name,
                "method": "winograd",
                "batch": 1,
                "hlo": hlo_rel,
                "input_shape": [c_in, h, w_sp],
                "output_shape": [c_out, s * h, s * w_sp],
                "golden_input": f"golden/{name}.in.bin",
                "golden_output": f"golden/{name}.out.bin",
            }
        )
        print(f"  wrote {name}")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument(
        "--methods", default="winograd,tdc",
        help="comma list of generator compute paths to AOT",
    )
    ap.add_argument("--batches", default=",".join(str(b) for b in GENERATOR_BATCHES))
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)
    methods = tuple(args.methods.split(","))
    batches = tuple(int(b) for b in args.batches.split(","))

    print(f"[aot] generators (scale={args.scale}, methods={methods}, batches={batches})")
    entries = emit_generators(out_dir, args.scale, methods, batches)
    print("[aot] single-layer ops")
    entries += emit_layer_ops(out_dir)

    manifest = {
        "version": 1,
        "scale": args.scale,
        "tolerance_note": "f32; rust integration tests use atol 2e-4 rel 2e-3",
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(entries)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
