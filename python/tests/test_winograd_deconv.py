"""The fused fast algorithm (TDC + Winograd + sparsity skip) vs the
standard-DeConv oracle — the paper's central correctness claim, exercised
through the Pallas engine."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, winograd_deconv as wd

PAPER_CONFIGS = [(5, 2), (4, 2), (3, 1)]


@pytest.mark.parametrize("k,s", PAPER_CONFIGS)
def test_matches_oracle_paper_configs(k, s):
    rng = np.random.default_rng(20)
    p = ref.default_padding(k, s)
    x = rng.standard_normal((3, 6, 8)).astype(np.float32)
    w = (rng.standard_normal((3, 4, k, k)) * 0.4).astype(np.float32)
    want = ref.deconv_naive(x.astype(np.float64), w.astype(np.float64), s, p)
    got = np.asarray(wd.winograd_deconv(jnp.asarray(x), jnp.asarray(w), s, p))
    np.testing.assert_allclose(got, want, atol=3e-4, rtol=3e-3)


def test_phase_plan_cases():
    # K=5/S=2 phases: (3,3) (3,2) (2,3) (2,2); K=4/S=2: all (2,2)
    plan5 = wd.phase_plan(5, 2, 2)
    assert [sup for _, sup, _ in plan5] == [(3, 3), (3, 2), (2, 3), (2, 2)]
    plan4 = wd.phase_plan(4, 2, 1)
    assert [sup for _, sup, _ in plan4] == [(2, 2)] * 4


def test_odd_spatial_sizes():
    rng = np.random.default_rng(21)
    x = rng.standard_normal((2, 5, 7)).astype(np.float32)
    w = (rng.standard_normal((2, 3, 5, 5)) * 0.4).astype(np.float32)
    want = ref.deconv_naive(x.astype(np.float64), w.astype(np.float64), 2, 2)
    got = np.asarray(wd.winograd_deconv(jnp.asarray(x), jnp.asarray(w), 2, 2))
    np.testing.assert_allclose(got, want, atol=3e-4, rtol=3e-3)


def test_single_pixel_input():
    rng = np.random.default_rng(22)
    x = rng.standard_normal((2, 1, 1)).astype(np.float32)
    w = rng.standard_normal((2, 2, 4, 4)).astype(np.float32)
    want = ref.deconv_naive(x.astype(np.float64), w.astype(np.float64), 2, 1)
    got = np.asarray(wd.winograd_deconv(jnp.asarray(x), jnp.asarray(w), 2, 1))
    np.testing.assert_allclose(got, want, atol=3e-4, rtol=3e-3)


def test_oracle_self_consistency():
    # winograd oracle in ref.py vs the Pallas path vs the naive oracle
    rng = np.random.default_rng(23)
    x64 = rng.standard_normal((2, 4, 4))
    w64 = rng.standard_normal((2, 2, 4, 4))
    naive = ref.deconv_naive(x64, w64, 2, 1)
    orc = ref.winograd_tdc_deconv(x64, w64, 2, 1)
    np.testing.assert_allclose(orc, naive, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(
    ks=st.sampled_from(PAPER_CONFIGS),
    c_in=st.integers(1, 3),
    c_out=st.integers(1, 3),
    h=st.integers(1, 6),
    w=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_fused_kernel_hypothesis(ks, c_in, c_out, h, w, seed):
    k, s = ks
    p = ref.default_padding(k, s)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((c_in, h, w)).astype(np.float32)
    wt = (rng.standard_normal((c_in, c_out, k, k)) * 0.5).astype(np.float32)
    want = ref.deconv_naive(x.astype(np.float64), wt.astype(np.float64), s, p)
    got = np.asarray(wd.winograd_deconv(jnp.asarray(x), jnp.asarray(wt), s, p))
    assert got.shape == (c_out, s * h, s * w)
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-3)


def test_dtype_bfloat16_loose():
    # bf16 inputs run through the same kernel (MXU-friendly dtype); loose
    # tolerance — this is a smoke-level numerics check
    rng = np.random.default_rng(24)
    x = jnp.asarray(rng.standard_normal((2, 4, 4)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((2, 2, 3, 3)) * 0.4, jnp.bfloat16)
    got = np.asarray(wd.winograd_deconv(x, w, 1, 1), dtype=np.float32)
    want = ref.deconv_naive(
        np.asarray(x, np.float64), np.asarray(w, np.float64), 1, 1
    )
    np.testing.assert_allclose(got, want, atol=0.15, rtol=0.15)
