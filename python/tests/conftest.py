"""Collection gate for offline environments.

The JAX/Pallas kernel tests need ``jax`` and ``hypothesis``; the build
container used for the rust tier-1 gate has neither. Skip collecting the
jax-backed modules when the imports are missing so ``python -m pytest
python/tests -q`` passes everywhere — ``test_ref_numpy.py`` (pure numpy)
always runs and keeps the oracle layer pinned.
"""

import importlib.util
import os
import sys

# make `compile.*` importable when pytest is run from the repo root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

_HAVE_JAX = importlib.util.find_spec("jax") is not None
_HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

collect_ignore = []
if not (_HAVE_JAX and _HAVE_HYPOTHESIS):
    collect_ignore = [
        "test_model.py",
        "test_sparsity.py",
        "test_tdc.py",
        "test_winograd.py",
        "test_winograd_deconv.py",
    ]
