"""Pure-numpy oracle tests — no jax, no hypothesis, always collected.

These mirror the rust golden-vector suite (rust/tests/golden_winograd.rs)
value for value, so the python oracle and the rust substrates are pinned to
the same hard-coded constants from both sides of the language boundary.
"""

import numpy as np

from compile.kernels import ref


def _rng():
    return np.random.default_rng(0xC0FFEE)


PAPER_CLASSES = [(5, 2, 2), (4, 2, 1), (3, 1, 1)]


def test_tdc_equals_naive_all_paper_classes():
    rng = _rng()
    for k, s, p in PAPER_CLASSES:
        x = rng.standard_normal((3, 5, 7))
        w = rng.standard_normal((3, 2, k, k))
        want = ref.deconv_naive(x, w, s, p)
        np.testing.assert_allclose(ref.tdc_deconv(x, w, s, p), want, atol=1e-12)


def test_zero_padded_equals_naive():
    rng = _rng()
    for k, s, p in PAPER_CLASSES:
        x = rng.standard_normal((2, 4, 6))
        w = rng.standard_normal((2, 3, k, k))
        want = ref.deconv_naive(x, w, s, p)
        np.testing.assert_allclose(ref.zero_padded_deconv(x, w, s, p), want, atol=1e-12)


def test_winograd_tdc_deconv_equals_naive():
    rng = _rng()
    for k, s, p in PAPER_CLASSES:
        x = rng.standard_normal((2, 6, 8))
        w = rng.standard_normal((2, 2, k, k))
        want = ref.deconv_naive(x, w, s, p)
        np.testing.assert_allclose(ref.winograd_tdc_deconv(x, w, s, p), want, atol=1e-9)


def test_filter_transform_golden_matches_rust_suite():
    # same golden as rust/tests/golden_winograd.rs::f23_filter_transform_golden
    f = np.arange(1.0, 10.0).reshape(1, 1, 3, 3)
    u = ref.winograd_filter_transform(f)[0, 0]
    want = np.array(
        [
            [1.0, 3.0, 1.0, 3.0],
            [6.0, 11.25, 3.75, 9.0],
            [2.0, 3.75, 1.25, 3.0],
            [7.0, 12.0, 4.0, 9.0],
        ]
    )
    np.testing.assert_array_equal(u, want)


def test_input_transform_golden_matches_rust_suite():
    z = np.arange(1.0, 17.0).reshape(4, 4)
    v = ref.winograd_input_transform(z)
    want = np.array(
        [
            [0.0, -16.0, 0.0, 0.0],
            [-4.0, 34.0, 2.0, -4.0],
            [0.0, 8.0, 0.0, 0.0],
            [0.0, -16.0, 0.0, 0.0],
        ]
    )
    np.testing.assert_array_equal(v, want)


def test_full_pipeline_golden_matches_rust_suite():
    z = np.arange(1.0, 17.0).reshape(4, 4)
    f = np.arange(1.0, 10.0).reshape(3, 3)
    u = ref.winograd_filter_transform(f.reshape(1, 1, 3, 3))[0, 0]
    v = ref.winograd_input_transform(z)
    y = ref.winograd_inverse_transform(u * v)
    np.testing.assert_array_equal(y, np.array([[348.0, 393.0], [528.0, 573.0]]))


def test_sparsity_pattern_counts():
    assert int(ref.sparsity_pattern(3, 3).sum()) == 16
    assert int(ref.sparsity_pattern(3, 2).sum()) == 12
    assert int(ref.sparsity_pattern(2, 3).sum()) == 12
    assert int(ref.sparsity_pattern(2, 2).sum()) == 9


def test_winograd_nonzero_counts_match_paper_eq5():
    assert ref.winograd_nonzero_count(5, 2, 2) == 49
    assert ref.winograd_nonzero_count(4, 2, 1) == 36
    assert ref.winograd_nonzero_count(3, 1, 1) == 16


def test_phase_taps_match_rust_structure():
    # K=5 S=2 P=2: phase 0 has 3 real taps at offset -1, phase 1 has 2 at 0
    taps0, d0 = ref.tdc_phase_taps_1d(5, 2, 2, 0)
    taps1, d1 = ref.tdc_phase_taps_1d(5, 2, 2, 1)
    assert sum(t >= 0 for t in taps0) == 3 and d0 == -1
    assert sum(t >= 0 for t in taps1) == 2 and d1 == 0
    assert ref.tdc_kc(5, 2) == 3
    assert ref.tdc_kc(4, 2) == 2
    assert ref.default_padding(5, 2) == 2


def test_activation_semantics_match_rust_goldens():
    # same hand-checkable values as rust/src/gan/zoo.rs::activation_semantics_golden
    x = np.array([-1.5, -1.0, 0.0, 0.5, 2.0])
    np.testing.assert_array_equal(
        ref.apply_activation(x, "relu"), np.array([0.0, 0.0, 0.0, 0.5, 2.0])
    )
    np.testing.assert_array_equal(
        ref.apply_activation(x, "lrelu"), np.array([-1.5 * 0.2, -0.2, 0.0, 0.5, 2.0])
    )
    np.testing.assert_array_equal(ref.apply_activation(x, "tanh"), np.tanh(x))
    np.testing.assert_array_equal(ref.apply_activation(x, "linear"), x)
    assert ref.ACTIVATIONS == ("linear", "relu", "lrelu", "tanh")


def test_activation_none_aliases_linear():
    # model.py spells the identity "none"; the oracle accepts both
    x = np.array([-1.0, 2.0])
    np.testing.assert_array_equal(ref.apply_activation(x, "none"), x)
