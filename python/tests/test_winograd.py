"""Winograd F(2x2,3x3): transform identities, structural sparsity, and the
Pallas accelerating engine vs direct correlation."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, winograd as wg


def test_transform_matrices_satisfy_winograd_identity():
    # F(2,3) 1D: A^T [(G f) ⊙ (B^T z)] == correlate(z, f), all z, f
    rng = np.random.default_rng(0)
    for _ in range(20):
        z = rng.standard_normal(4)
        f = rng.standard_normal(3)
        lhs = ref.AT @ ((ref.G @ f) * (ref.BT @ z))
        want = np.array([z[0]*f[0] + z[1]*f[1] + z[2]*f[2],
                         z[1]*f[0] + z[2]*f[1] + z[3]*f[2]])
        np.testing.assert_allclose(lhs, want, atol=1e-12)


def test_filter_transform_pads_small_supports():
    rng = np.random.default_rng(1)
    g2 = rng.standard_normal((1, 1, 2, 2))
    u = ref.winograd_filter_transform(g2)
    assert u.shape == (1, 1, 4, 4)
    # padded 2-tap support zeroes the 4th row and column
    np.testing.assert_array_equal(u[0, 0, 3, :], 0.0)
    np.testing.assert_array_equal(u[0, 0, :, 3], 0.0)


@pytest.mark.parametrize("ry,rx,case,live", [
    (3, 3, 1, 16), (3, 2, 2, 12), (2, 3, 2, 12), (2, 2, 3, 9),
])
def test_sparsity_cases(ry, rx, case, live):
    mask = ref.sparsity_pattern(ry, rx)
    assert int(mask.sum()) == live
    nz = wg.nonzero_positions(ry, rx)
    assert len(nz) == live
    assert wg.sparsity_case(ry, rx) == case
    # positions agree with the mask
    flat = mask.reshape(-1)
    assert all(flat[p] for p in nz)
    assert sum(flat) == len(nz)


def test_c_of_kc_constants():
    assert ref.winograd_nonzero_count(5, 2, 2) == 49
    assert ref.winograd_nonzero_count(4, 2, 1) == 36
    assert ref.winograd_nonzero_count(3, 1, 1) == 16


def test_extract_tiles_overlap():
    x = jnp.arange(1 * 6 * 6, dtype=jnp.float32).reshape(1, 6, 6)
    t = np.asarray(wg.extract_tiles(x, 2, 2))
    assert t.shape == (4, 1, 4, 4)
    # stride-2 overlapping windows
    np.testing.assert_array_equal(t[0, 0], np.asarray(x)[0, 0:4, 0:4])
    np.testing.assert_array_equal(t[1, 0], np.asarray(x)[0, 0:4, 2:6])
    np.testing.assert_array_equal(t[3, 0], np.asarray(x)[0, 2:6, 2:6])


@pytest.mark.parametrize("r", [2, 3])
def test_pallas_winograd_conv_matches_oracle(r):
    rng = np.random.default_rng(2)
    x = rng.standard_normal((3, 8, 10)).astype(np.float32)
    g = rng.standard_normal((3, 4, r, r)).astype(np.float32) * 0.4
    got = np.asarray(wg.winograd_conv2d(jnp.asarray(x), jnp.asarray(g)))
    g3 = np.zeros((3, 4, 3, 3))
    g3[:, :, :r, :r] = g
    want = ref.correlate_valid(x.astype(np.float64), g3)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_engine_skips_structural_zeros_but_same_result():
    # forcing the dense Case-1 path on a 2x2 filter must give the same
    # output as the sparse Case-3 path (ablation hook used by the benches)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 6, 6)).astype(np.float32))
    g = jnp.asarray(rng.standard_normal((2, 2, 2, 2)).astype(np.float32))
    sparse = np.asarray(wg.winograd_conv2d(x, g))            # r inferred = 2
    dense = np.asarray(wg.winograd_conv2d(x, g, r_y=3, r_x=3))  # force Case 1
    np.testing.assert_allclose(sparse, dense, atol=1e-5, rtol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    c_in=st.integers(1, 3),
    c_out=st.integers(1, 4),
    th=st.integers(1, 4),
    tw=st.integers(1, 4),
    r=st.integers(2, 3),
    seed=st.integers(0, 2**16),
)
def test_pallas_engine_hypothesis(c_in, c_out, th, tw, r, seed):
    rng = np.random.default_rng(seed)
    h, w = 2 * th + 2, 2 * tw + 2
    x = rng.standard_normal((c_in, h, w)).astype(np.float32)
    g = rng.standard_normal((c_in, c_out, r, r)).astype(np.float32)
    got = np.asarray(wg.winograd_conv2d(jnp.asarray(x), jnp.asarray(g)))
    g3 = np.zeros((c_in, c_out, 3, 3))
    g3[:, :, :r, :r] = g
    want = ref.correlate_valid(x.astype(np.float64), g3)
    np.testing.assert_allclose(got, want, atol=3e-4, rtol=3e-3)


def test_tile_block_boundary_handling():
    # tile counts that don't divide TILE_BLOCK exercise the padding path
    rng = np.random.default_rng(4)
    x = rng.standard_normal((1, 12, 12)).astype(np.float32)  # 25 tiles
    g = rng.standard_normal((1, 1, 3, 3)).astype(np.float32)
    got = np.asarray(wg.winograd_conv2d(jnp.asarray(x), jnp.asarray(g)))
    want = ref.correlate_valid(x.astype(np.float64), g.astype(np.float64))
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)
    # and a tiny tile_block forces multiple grid steps
    z = wg.extract_tiles(jnp.asarray(x), 5, 5)
    u = wg.filter_transform(jnp.asarray(g))
    nz = wg.nonzero_positions(3, 3)
    u_nz = jnp.transpose(u.reshape(1, 1, 16), (2, 1, 0))[jnp.asarray(nz)]
    y_small = np.asarray(wg.winograd_engine(z, u_nz, nz, tile_block=4))
    y_big = np.asarray(wg.winograd_engine(z, u_nz, nz, tile_block=64))
    np.testing.assert_allclose(y_small, y_big, atol=1e-6)
