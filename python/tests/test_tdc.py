"""TDC decomposition: JAX implementation vs the numpy oracle, with
hypothesis sweeps over shapes, kernel sizes, strides and paddings.

The core claim under test is the paper's Fig. 2 equivalence: the TDC
method computes exactly the standard DeConv."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, tdc

PAPER_CONFIGS = [(5, 2), (4, 2), (3, 1)]


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize("k,s", PAPER_CONFIGS)
def test_kc_matches_table1(k, s):
    expected = {(5, 2): 3, (4, 2): 2, (3, 1): 3}[(k, s)]
    assert tdc.tdc_kc(k, s) == expected


@pytest.mark.parametrize("k,s", PAPER_CONFIGS)
def test_tdc_deconv_equals_oracle(k, s):
    rng = np.random.default_rng(10)
    p = ref.default_padding(k, s)
    x = rand(rng, 3, 6, 5)
    w = rand(rng, 3, 4, k, k)
    want = ref.deconv_naive(x.astype(np.float64), w.astype(np.float64), s, p)
    got = np.asarray(tdc.tdc_deconv(jnp.asarray(x), jnp.asarray(w), s, p))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("k,s", PAPER_CONFIGS)
def test_zero_padded_deconv_equals_oracle(k, s):
    rng = np.random.default_rng(11)
    p = ref.default_padding(k, s)
    x = rand(rng, 2, 4, 7)
    w = rand(rng, 2, 3, k, k)
    want = ref.deconv_naive(x.astype(np.float64), w.astype(np.float64), s, p)
    got = np.asarray(tdc.zero_padded_deconv(jnp.asarray(x), jnp.asarray(w), s, p))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_decompose_structural_support_k5():
    rng = np.random.default_rng(12)
    w = rand(rng, 1, 1, 5, 5)
    g, d0 = ref.tdc_decompose(w.astype(np.float64), 2, 2)
    assert g.shape == (2, 2, 1, 1, 3, 3)
    # phase (0,0) dense 3x3; (1,1) has only a 2x2 live corner
    assert np.count_nonzero(g[0, 0]) == 9
    assert np.count_nonzero(g[1, 1]) == 4
    assert np.count_nonzero(g[0, 1]) == 6
    assert (d0 <= 0).all()


def test_phase_taps_cover_all_kernel_taps_exactly_once():
    # every kernel tap is used by exactly one phase (partition property)
    for k, s in PAPER_CONFIGS + [(6, 3), (7, 2)]:
        p = ref.default_padding(k, s)
        seen = []
        for phase in range(s):
            taps, _ = ref.tdc_phase_taps_1d(k, s, p, phase)
            seen.extend(t for t in taps if t >= 0)
        assert sorted(seen) == list(range(k)), f"K={k} S={s}"


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(2, 6),
    s=st.integers(1, 3),
    c_in=st.integers(1, 3),
    c_out=st.integers(1, 3),
    h=st.integers(1, 6),
    w=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_tdc_equivalence_hypothesis(k, s, c_in, c_out, h, w, seed):
    if s > k:
        s = k  # degenerate: stride beyond kernel unsupported by padding rule
    p = ref.default_padding(k, s)
    kc = ref.tdc_kc(k, s)
    # uniform-K_C decomposition requires the offset bound (asserted in ref)
    pad = k - 1 - p
    if not (0 <= pad and p <= k - 1):
        return
    d0_min = (0 + ((pad) % s) - pad) // s if s else 0
    if d0_min < -(kc - 1):
        return
    rng = np.random.default_rng(seed)
    x = rand(rng, c_in, h, w).astype(np.float64)
    wt = rand(rng, c_in, c_out, k, k).astype(np.float64)
    want = ref.deconv_naive(x, wt, s, p)
    got = ref.tdc_deconv(x, wt, s, p)
    np.testing.assert_allclose(got, want, atol=1e-10)
    got_jax = np.asarray(
        tdc.tdc_deconv(jnp.asarray(x, jnp.float32), jnp.asarray(wt, jnp.float32), s, p)
    )
    np.testing.assert_allclose(got_jax, want, atol=1e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    c_in=st.integers(1, 3),
    h=st.integers(2, 5),
    seed=st.integers(0, 2**16),
)
def test_zero_padded_equivalence_hypothesis(c_in, h, seed):
    rng = np.random.default_rng(seed)
    for k, s in PAPER_CONFIGS:
        p = ref.default_padding(k, s)
        x = rand(rng, c_in, h, h).astype(np.float64)
        wt = rand(rng, c_in, 2, k, k).astype(np.float64)
        want = ref.deconv_naive(x, wt, s, p)
        got = ref.zero_padded_deconv(x, wt, s, p)
        np.testing.assert_allclose(got, want, atol=1e-10)


def test_interleave_phases_layout():
    # 2x2 phases of constant maps interleave into the right checkerboard
    s = 2
    phases = [
        [jnp.full((1, 2, 2), 0.0), jnp.full((1, 2, 2), 1.0)],
        [jnp.full((1, 2, 2), 2.0), jnp.full((1, 2, 2), 3.0)],
    ]
    y = np.asarray(tdc.interleave_phases(phases, s))[0]
    assert y.shape == (4, 4)
    assert y[0, 0] == 0.0 and y[0, 1] == 1.0
    assert y[1, 0] == 2.0 and y[1, 1] == 3.0
    assert y[2, 2] == 0.0 and y[3, 3] == 3.0
