"""L2 generator models: zoo geometry (Table I), forward shapes, and the
equivalence of the three compute paths at the whole-generator level."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref


def test_zoo_matches_table1():
    z = M.zoo("paper")
    assert set(z) == {"dcgan", "artgan", "discogan", "gpgan"}
    d = z["dcgan"]
    deconvs = [l for l in d.layers if l.kind == "deconv"]
    assert len(deconvs) == 4
    assert all(l.k == 5 and l.s == 2 and l.kc == 3 for l in deconvs)

    a = z["artgan"]
    ks = [(l.k, l.s, l.kc) for l in a.layers if l.kind == "deconv"]
    assert ks.count((4, 2, 2)) == 4
    assert ks.count((3, 1, 3)) == 1

    disco = z["discogan"]
    assert sum(1 for l in disco.layers if l.kind == "conv") == 5
    assert sum(1 for l in disco.layers if l.kind == "deconv") == 4

    gp = z["gpgan"]
    assert all(l.kc == 2 for l in gp.layers if l.kind == "deconv")


def test_zoo_spatial_chains():
    for scale in ("paper", "small"):
        for name, cfg in M.zoo(scale).items():
            prev = None
            for l in cfg.layers:
                if prev is not None:
                    c, h, w = prev
                    assert (c, h, w) == (l.c_in, l.h_in, l.w_in), f"{name} chain broken"
                prev = (l.c_out, l.h_out, l.w_out)
            assert prev == (3, 64, 64), name


@pytest.mark.parametrize("name", ["dcgan", "gpgan"])
def test_forward_shapes_small(name):
    cfg = M.zoo("small")[name]
    params = M.init_params(cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(cfg.input_shape), jnp.float32)
    y = M.forward(cfg, params, x, method="tdc")
    assert y.shape == cfg.output_shape
    assert np.isfinite(np.asarray(y)).all()
    # tanh output bounded
    assert float(jnp.abs(y).max()) <= 1.0 + 1e-6


def test_methods_compute_same_function_tiny():
    # tiny custom generator (fast even through interpret-mode pallas)
    cfg = M.GanCfg(
        name="tiny",
        z_dim=8,
        layers=(
            M.LayerCfg("deconv", 6, 4, 5, 2, 2, 4, 4, "relu"),
            M.LayerCfg("deconv", 4, 3, 4, 2, 1, 8, 8, "tanh", norm=False),
        ),
    )
    params = M.init_params(cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
    outs = {m: np.asarray(M.forward(cfg, params, x, method=m)) for m in M.METHODS}
    np.testing.assert_allclose(outs["winograd"], outs["zero_pad"], atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(outs["tdc"], outs["zero_pad"], atol=2e-4, rtol=2e-3)


def test_batched_forward_is_vmap_of_single():
    cfg = M.GanCfg(
        name="tiny2",
        z_dim=4,
        layers=(M.LayerCfg("deconv", 4, 3, 4, 2, 1, 4, 4, "tanh", norm=False),),
    )
    params = M.init_params(cfg)
    rng = np.random.default_rng(2)
    xb = jnp.asarray(rng.standard_normal((3, 4)), jnp.float32)
    batched = np.asarray(M.batched_forward(cfg, params, method="tdc")(xb))
    for i in range(3):
        single = np.asarray(M.forward(cfg, params, xb[i], method="tdc"))
        np.testing.assert_allclose(batched[i], single, atol=1e-5)


def test_image_to_image_model_shapes():
    cfg = M.zoo("small")["discogan"]
    assert cfg.z_dim is None
    assert cfg.input_shape == (3, 64, 64)
    params = M.init_params(cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(np.tanh(rng.standard_normal(cfg.input_shape)), jnp.float32)
    y = M.forward(cfg, params, x, method="tdc")
    assert y.shape == (3, 64, 64)


def test_init_params_deterministic():
    cfg = M.zoo("small")["dcgan"]
    a = M.init_params(cfg)
    b = M.init_params(cfg)
    np.testing.assert_array_equal(np.asarray(a["proj_w"]), np.asarray(b["proj_w"]))
    for la, lb in zip(a["layers"], b["layers"]):
        np.testing.assert_array_equal(np.asarray(la["w"]), np.asarray(lb["w"]))


def test_layer_cfg_helpers():
    l = M.LayerCfg("deconv", 8, 4, 5, 2, 2, 4, 4, "relu")
    assert (l.h_out, l.w_out) == (8, 8)
    assert l.kc == 3
    c = M.LayerCfg("conv", 8, 4, 4, 2, 1, 8, 8, "lrelu")
    assert (c.h_out, c.w_out) == (4, 4)


def test_paddings_follow_paper():
    for k, s in [(5, 2), (4, 2), (3, 1)]:
        p = ref.default_padding(k, s)
        # H_O = S*H requires output_padding S-K+2P >= 0
        assert ref.deconv_output_padding(k, s, p) >= 0
