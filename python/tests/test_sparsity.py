"""Structural sparsity invariants (Fig. 3/6) and the Fig. 4 multiplication
model — the quantities the rust substrates mirror (rust/src/winograd,
rust/src/gan/workload)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def test_transformed_zero_positions_are_exact():
    # prediction from sparsity_pattern == actual zeros of G f G^T
    rng = np.random.default_rng(0)
    for ry in (1, 2, 3):
        for rx in (1, 2, 3):
            g = np.zeros((1, 1, 3, 3))
            g[0, 0, :ry, :rx] = rng.standard_normal((ry, rx))
            u = ref.winograd_filter_transform(g)[0, 0]
            mask = ref.sparsity_pattern(ry, rx)
            # predicted-zero positions are exactly zero
            assert np.all(u[~mask] == 0.0), (ry, rx)
            # predicted-live positions are generically non-zero
            assert np.all(np.abs(u[mask]) > 1e-12), (ry, rx)


def test_case_counts_match_paper_fig6():
    # Case 1: no zero rows; Case 2: n zero rows; Case 3: 2n-1 zero rows
    n = ref.N_TILE
    assert int((~ref.sparsity_pattern(3, 3)).sum()) == 0
    assert int((~ref.sparsity_pattern(3, 2)).sum()) == n
    assert int((~ref.sparsity_pattern(2, 2)).sum()) == 2 * n - 1


@pytest.mark.parametrize("k,s,expected", [(5, 2, 49), (4, 2, 36), (3, 1, 16)])
def test_c_of_kc_eq5(k, s, expected):
    assert ref.winograd_nonzero_count(k, s, ref.default_padding(k, s)) == expected


def test_fig4_reduction_ratios():
    # layer-level ratios the paper quotes: ZP/Win = 8.16 for K5S2,
    # 64/9 for K4S2; TDC/Win = 36/12.25, 16/9
    m, n, h, w = 64, 64, 16, 16
    zp5 = ref.mults_zero_padded(m, n, h, w, 5, 2)
    td5 = ref.mults_tdc(m, n, h, w, 5, 2)
    wi5 = ref.mults_winograd(m, n, h, w, 5, 2, 2)
    assert abs(zp5 / wi5 - 8.163) < 0.01
    assert abs(td5 / wi5 - 36 / 12.25) < 0.01
    zp4 = ref.mults_zero_padded(m, n, h, w, 4, 2)
    wi4 = ref.mults_winograd(m, n, h, w, 4, 2, 1)
    assert abs(zp4 / wi4 - 64 / 9) < 0.01


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(2, 6),
    s=st.integers(1, 3),
    m=st.integers(1, 64),
    n=st.integers(1, 64),
    h=st.integers(2, 32),
)
def test_mult_ordering_hypothesis(k, s, m, n, h):
    if s > k:
        return
    p = ref.default_padding(k, s)
    kc = ref.tdc_kc(k, s)
    if kc > 3:
        return  # beyond F(2x2,3x3) support
    try:
        wi = ref.mults_winograd(m, n, h, h, k, s, p)
    except AssertionError:
        return  # decomposition offset bound not satisfied for this (k,s,p)
    zp = ref.mults_zero_padded(m, n, h, h, k, s)
    td = ref.mults_tdc(m, n, h, h, k, s)
    assert td <= zp
    if kc >= 2:
        # the regime the paper targets (Table I: K_C in {2, 3}) — Winograd
        # always reduces multiplications there
        assert wi <= td
    else:
        # K_C = 1 boundary: padding a 1-tap filter to 3x3 costs 9/4 mults
        # per output vs 1 for direct TDC — Winograd is a net LOSS, which is
        # why the paper (and our accelerator) only applies F(2x2,3x3) to
        # the K_C >= 2 classes
        assert wi > td
    # floor: at least 9 live positions per tile survive the zero-skipping
    assert wi >= m * n * math.ceil(h / 2) * math.ceil(h / 2) * 9


def test_zero_rows_are_whole_vectors_in_reordered_layout():
    # vector-level sparsity claim: in the n^2 x N layout, a structural zero
    # is zero for EVERY channel (whole row), not scattered
    rng = np.random.default_rng(1)
    c_in, c_out = 5, 3
    g = np.zeros((c_in, c_out, 3, 3))
    g[:, :, :2, :2] = rng.standard_normal((c_in, c_out, 2, 2))
    u = ref.winograd_filter_transform(g)  # [ci, co, 4, 4]
    flat = u.reshape(c_in, c_out, 16)
    mask = ref.sparsity_pattern(2, 2).reshape(16)
    for pos in range(16):
        col = flat[:, :, pos]
        if mask[pos]:
            assert np.any(col != 0.0)
        else:
            assert np.all(col == 0.0), f"position {pos} not a whole zero row"
